//! Worker-pool substrate (no rayon offline): a fixed set of threads pulling
//! boxed jobs from *sharded* (striped) queues — one stripe per worker, with
//! work stealing — plus a scoped map helper for data-parallel solver work.
//!
//! §Perf: the previous pool funneled every pop through a single
//! `Mutex<Receiver>`, so at high tile rates workers serialized on the
//! channel lock. Dispatch is now striped: `submit` round-robins jobs over
//! per-worker `Mutex<VecDeque>` stripes (each lock touched by one worker in
//! the common case), `submit_many` enqueues a whole batch with one lock
//! acquisition per stripe, and idle workers steal from neighboring stripes
//! before sleeping on a condvar. The bounded-capacity backpressure
//! semantics of the old pool are preserved.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Shared pool state: striped job queues + sleep/wake machinery.
struct PoolState {
    /// One stripe per worker; `submit` round-robins across them and worker
    /// `i` always tries stripe `i` first, so under load each lock is
    /// touched by one producer hand-off and one consumer.
    stripes: Vec<Mutex<VecDeque<Job>>>,
    /// Jobs enqueued but not yet popped (not "not yet completed").
    pending: AtomicUsize,
    /// Workers currently asleep on `work_cv`.
    sleepers: AtomicUsize,
    /// Producers currently asleep on `space_cv` (capacity backpressure).
    waiters: AtomicUsize,
    closed: AtomicBool,
    /// Guards the sleep/wake protocol only — never held while running a
    /// job or while a stripe lock is held.
    sleep: Mutex<()>,
    work_cv: Condvar,
    space_cv: Condvar,
    /// Queue capacity: `submit` blocks while `pending >= cap`.
    cap: usize,
    /// Round-robin submission cursor.
    rr: AtomicUsize,
    submitted: AtomicUsize,
    completed: AtomicUsize,
}

impl PoolState {
    // Lock-poison recovery (not propagation): a panicking job poisons
    // whatever stripe/sleep lock its worker holds, but every protected
    // value is a plain `VecDeque` (or `()`), consistent at each lock
    // release — so the poison flag carries no torn state and taking the
    // guard back is sound. Recovering keeps one bad job from wedging
    // every later submit/pop on a "poisoned" panic.
    fn lock_sleep(&self) -> MutexGuard<'_, ()> {
        self.sleep.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Pop one job, trying stripe `home` first then stealing round-robin.
    ///
    /// §Perf (steal-half batching): a steal takes a *run* of half the
    /// victim's queue under one lock acquisition and re-homes the surplus
    /// onto the thief's own stripe, so a worker that finds a loaded victim
    /// does not return to the victim's lock for every subsequent job —
    /// on very large models a single `submit_many` burst lands on few
    /// stripes and the old one-job steals serialized every idle worker on
    /// those locks. The surplus jobs stay *enqueued* (only the returned
    /// job is popped; `pending` counts enqueued-not-popped and is
    /// decremented by the caller exactly once), so the
    /// pending-count-before-publish invariant is untouched, and the two
    /// stripe locks are never held simultaneously.
    fn pop(&self, home: usize) -> Option<Job> {
        let s = self.stripes.len();
        let lock_stripe = |i: usize| self.stripes[i].lock().unwrap_or_else(|e| e.into_inner());
        if let Some(job) = lock_stripe(home).pop_front() {
            return Some(job);
        }
        for k in 1..s {
            let victim = (home + k) % s;
            let mut run: VecDeque<Job> = {
                let mut q = lock_stripe(victim);
                let take = q.len().div_ceil(2);
                q.drain(..take).collect()
            };
            if let Some(job) = run.pop_front() {
                if !run.is_empty() {
                    let mut mine = lock_stripe(home);
                    mine.extend(run);
                }
                return Some(job);
            }
        }
        None
    }

    /// Push `jobs` onto stripe `idx` under one lock acquisition.
    fn push_batch(&self, idx: usize, jobs: impl IntoIterator<Item = Job>) {
        let mut q = self.stripes[idx % self.stripes.len()]
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        q.extend(jobs);
    }

    /// Block until the queue has room for ~`n` more jobs (Dekker-style
    /// handshake with the workers' `waiters` check; SeqCst on both sides).
    fn wait_for_space(&self, n: usize) {
        let want = self.cap.saturating_sub(n.min(self.cap));
        while self.pending.load(Ordering::SeqCst) > want && !self.closed.load(Ordering::SeqCst) {
            let mut guard = self.lock_sleep();
            self.waiters.fetch_add(1, Ordering::SeqCst);
            if self.pending.load(Ordering::SeqCst) > want && !self.closed.load(Ordering::SeqCst) {
                guard = self.space_cv.wait(guard).unwrap_or_else(|e| e.into_inner());
            }
            self.waiters.fetch_sub(1, Ordering::SeqCst);
            drop(guard);
        }
    }

    /// Wake sleeping workers after enqueueing `n` jobs. Producers touch the
    /// sleep lock only when a worker is actually parked (SeqCst pairs with
    /// the worker's recheck-under-lock, so no wakeup is lost).
    fn wake_workers(&self, n: usize) {
        if self.sleepers.load(Ordering::SeqCst) > 0 {
            let _guard = self.lock_sleep();
            if n == 1 {
                self.work_cv.notify_one();
            } else {
                self.work_cv.notify_all();
            }
        }
    }

    /// Signal producers blocked on capacity after a pop.
    fn signal_space(&self) {
        if self.waiters.load(Ordering::SeqCst) > 0 {
            let _guard = self.lock_sleep();
            self.space_cv.notify_all();
        }
    }
}

/// Fixed-size thread pool with striped bounded queues. `submit` blocks when
/// the queues are at capacity (backpressure), so producers can't outrun the
/// workers; `submit_many` enqueues a batch with one lock acquisition per
/// stripe.
pub struct ThreadPool {
    state: Arc<PoolState>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    pub fn new(threads: usize, queue_cap: usize) -> Self {
        let threads = threads.max(1);
        let state = Arc::new(PoolState {
            stripes: (0..threads).map(|_| Mutex::new(VecDeque::new())).collect(),
            pending: AtomicUsize::new(0),
            sleepers: AtomicUsize::new(0),
            waiters: AtomicUsize::new(0),
            closed: AtomicBool::new(false),
            sleep: Mutex::new(()),
            work_cv: Condvar::new(),
            space_cv: Condvar::new(),
            cap: queue_cap.max(1),
            rr: AtomicUsize::new(0),
            submitted: AtomicUsize::new(0),
            completed: AtomicUsize::new(0),
        });
        let workers = (0..threads)
            .map(|i| {
                let state = Arc::clone(&state);
                std::thread::Builder::new()
                    .name(format!("msb-worker-{i}"))
                    .spawn(move || worker_loop(&state, i))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { state, workers, size: threads }
    }

    /// Default pool: one worker per available core.
    pub fn with_default_size() -> Self {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        ThreadPool::new(n, n * 4)
    }

    /// Enqueue a job; blocks when the queues are at capacity.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        assert!(!self.state.closed.load(Ordering::SeqCst), "pool already shut down");
        self.state.wait_for_space(1);
        let idx = self.state.rr.fetch_add(1, Ordering::Relaxed);
        self.state.submitted.fetch_add(1, Ordering::Release);
        // count BEFORE publishing: a worker that pops the job immediately
        // must never drive `pending` below zero (it is unsigned)
        self.state.pending.fetch_add(1, Ordering::SeqCst);
        self.state.push_batch(idx, std::iter::once(Box::new(job) as Job));
        self.state.wake_workers(1);
    }

    /// Enqueue a batch of jobs with one stripe-lock acquisition per worker
    /// stripe — the low-contention path the model-global scheduler uses to
    /// dump hundreds of tiles at once. Blocks for capacity once up front;
    /// a batch may transiently overshoot the bound by its own length.
    pub fn submit_many<I, F>(&self, jobs: I)
    where
        I: IntoIterator<Item = F>,
        F: FnOnce() + Send + 'static,
    {
        assert!(!self.state.closed.load(Ordering::SeqCst), "pool already shut down");
        let jobs: Vec<Job> = jobs.into_iter().map(|j| Box::new(j) as Job).collect();
        let n = jobs.len();
        if n == 0 {
            return;
        }
        self.state.wait_for_space(n);
        let stripes = self.state.stripes.len();
        let base = self.state.rr.fetch_add(n, Ordering::Relaxed);
        self.state.submitted.fetch_add(n, Ordering::Release);
        // count BEFORE publishing (see `submit`); workers that drain early
        // chunks while later ones are still being dealt stay non-negative
        self.state.pending.fetch_add(n, Ordering::SeqCst);
        // deal the batch into `stripes` contiguous runs, one lock each
        let chunk = n.div_ceil(stripes);
        let mut it = jobs.into_iter();
        let mut stripe = base;
        loop {
            let run: Vec<Job> = it.by_ref().take(chunk).collect();
            if run.is_empty() {
                break;
            }
            self.state.push_batch(stripe, run);
            stripe += 1;
        }
        self.state.wake_workers(n);
    }

    /// Worker count the pool was built with (stable across shutdown).
    pub fn threads(&self) -> usize {
        self.size
    }

    /// `(submitted, completed)` job counts. After [`ThreadPool::shutdown`]
    /// the two are equal: the join synchronizes every completion.
    pub fn stats(&self) -> (usize, usize) {
        (
            self.state.submitted.load(Ordering::Acquire),
            self.state.completed.load(Ordering::Acquire),
        )
    }

    /// Close the queues and join all workers (the queues drain first).
    /// Idempotent; the pool remains readable (`stats`) afterwards.
    pub fn shutdown(&mut self) {
        self.state.closed.store(true, Ordering::SeqCst);
        {
            // serialize with any worker between its recheck and its wait
            let _guard = self.state.lock_sleep();
        }
        self.state.work_cv.notify_all();
        self.state.space_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_loop(state: &PoolState, home: usize) {
    loop {
        match state.pop(home) {
            Some(job) => {
                state.pending.fetch_sub(1, Ordering::SeqCst);
                state.signal_space();
                job();
                state.completed.fetch_add(1, Ordering::Release);
            }
            None => {
                let guard = state.lock_sleep();
                state.sleepers.fetch_add(1, Ordering::SeqCst);
                // recheck under the lock: a producer that missed our
                // sleepers increment must have published its count first
                // (SeqCst), and one that saw it will notify under the lock
                if state.pending.load(Ordering::SeqCst) == 0 {
                    if state.closed.load(Ordering::SeqCst) {
                        state.sleepers.fetch_sub(1, Ordering::SeqCst);
                        return;
                    }
                    let guard = state.work_cv.wait(guard).unwrap_or_else(|e| e.into_inner());
                    drop(guard);
                } else {
                    // pending is counted before jobs are published, so a
                    // push may still be in flight: yield instead of
                    // hot-spinning on the stripe locks
                    drop(guard);
                    std::thread::yield_now();
                }
                state.sleepers.fetch_sub(1, Ordering::SeqCst);
            }
        }
    }
}

/// Data-parallel map over items using scoped threads: results keep input
/// order; panics propagate. Unlike [`ThreadPool`] jobs (which must be
/// `'static`), the closure may borrow local state — this is the crate's
/// fan-out utility for callers with non-owned data, now that the pipeline
/// itself schedules through the model-global queue.
///
/// §Perf: work is claimed through a single atomic cursor and every result
/// lands in its own per-slot cell, so neither the claim nor the write ever
/// serializes behind a shared lock (the old implementation funneled all
/// result writes through one `Mutex<&mut Vec<_>>`).
pub fn scoped_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Send + Sync,
{
    let threads = threads.max(1);
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    if threads == 1 || n == 1 {
        return items.into_iter().map(f).collect();
    }
    // per-slot cells: each item/result owns its own (uncontended) lock
    let work: Vec<Mutex<Option<T>>> = items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads.min(n) {
            s.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let t = work[i].lock().expect("scoped_map item").take().expect("item taken twice");
                let r = f(t);
                *slots[i].lock().expect("scoped_map slot") = Some(r);
            });
        }
    });
    slots
        .into_iter()
        .map(|c| c.into_inner().expect("scoped_map slot poisoned").expect("slot unfilled"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let mut pool = ThreadPool::new(4, 8);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.shutdown();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn pool_stats() {
        let mut pool = ThreadPool::new(2, 4);
        assert_eq!(pool.threads(), 2);
        for _ in 0..10 {
            pool.submit(|| {});
        }
        pool.shutdown();
        // the join synchronizes: every submitted job is also completed
        assert_eq!(pool.stats(), (10, 10));
        // shutdown is idempotent and stats stay readable
        pool.shutdown();
        assert_eq!(pool.stats(), (10, 10));
        assert_eq!(pool.threads(), 2);
    }

    #[test]
    fn backpressure_blocks_but_completes() {
        // tiny queue, slow jobs: submit must block rather than drop
        let mut pool = ThreadPool::new(1, 1);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..20 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                std::thread::sleep(std::time::Duration::from_micros(200));
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.shutdown();
        assert_eq!(counter.load(Ordering::Relaxed), 20);
    }

    #[test]
    fn submit_many_runs_all_and_counts() {
        let mut pool = ThreadPool::new(3, 256);
        let counter = Arc::new(AtomicU64::new(0));
        pool.submit_many((0..200u64).map(|i| {
            let c = Arc::clone(&counter);
            move || {
                c.fetch_add(i, Ordering::Relaxed);
            }
        }));
        pool.submit_many(std::iter::empty::<fn()>());
        pool.shutdown();
        assert_eq!(counter.load(Ordering::Relaxed), (0..200).sum::<u64>());
        assert_eq!(pool.stats(), (200, 200));
    }

    #[test]
    fn submit_many_interleaves_with_submit() {
        // batch + singleton submissions from several concurrent producer
        // threads must all drain; exercises the striped queues and the
        // wake protocol under real submission contention
        let mut pool = ThreadPool::new(4, 64);
        let counter = Arc::new(AtomicU64::new(0));
        std::thread::scope(|s| {
            let pool = &pool;
            for p in 0..4 {
                let counter = Arc::clone(&counter);
                s.spawn(move || {
                    if p % 2 == 0 {
                        pool.submit_many((0..50u64).map(|_| {
                            let c = Arc::clone(&counter);
                            move || {
                                c.fetch_add(1, Ordering::Relaxed);
                            }
                        }));
                    } else {
                        for _ in 0..50 {
                            let c = Arc::clone(&counter);
                            pool.submit(move || {
                                c.fetch_add(1, Ordering::Relaxed);
                            });
                        }
                    }
                });
            }
        });
        pool.shutdown();
        assert_eq!(counter.load(Ordering::Relaxed), 200);
        assert_eq!(pool.stats(), (200, 200));
    }

    /// Steal-half batching: a burst landing on few stripes (single
    /// producer, one `submit_many`) while most workers idle must drain
    /// completely with exact accounting — the surplus of each steal run is
    /// re-homed but never popped twice, never lost, and `pending` (counted
    /// before publish, decremented once per pop) never underflows.
    #[test]
    fn steal_half_drains_bursts_with_exact_accounting() {
        let mut pool = ThreadPool::new(8, 4096);
        let counter = Arc::new(AtomicU64::new(0));
        for round in 0..4 {
            pool.submit_many((0..500u64).map(|i| {
                let c = Arc::clone(&counter);
                move || {
                    if i % 97 == 0 {
                        std::thread::sleep(std::time::Duration::from_micros(100));
                    }
                    c.fetch_add(1, Ordering::Relaxed);
                }
            }));
            // interleave singleton submissions so thieves race producers
            for _ in 0..25 {
                let c = Arc::clone(&counter);
                pool.submit(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
            let _ = round;
        }
        pool.shutdown();
        assert_eq!(counter.load(Ordering::Relaxed), 4 * 525);
        assert_eq!(pool.stats(), (4 * 525, 4 * 525));
    }

    /// A panicking job poisons whichever stripe lock its worker touches
    /// next and kills that worker thread, but the pool must not wedge:
    /// later `submit_many` batches drain completely on the surviving
    /// workers (poison recovery instead of `expect` aborts), and the
    /// accounting shows exactly one submitted-but-never-completed job.
    #[test]
    fn faulty_job_does_not_wedge_subsequent_batches() {
        let mut pool = ThreadPool::new(2, 64);
        let counter = Arc::new(AtomicU64::new(0));
        pool.submit(|| panic!("injected job fault"));
        for _ in 0..3 {
            pool.submit_many((0..50u64).map(|_| {
                let c = Arc::clone(&counter);
                move || {
                    c.fetch_add(1, Ordering::Relaxed);
                }
            }));
        }
        pool.shutdown();
        assert_eq!(counter.load(Ordering::Relaxed), 150);
        // the injected fault is the one job submitted but never completed
        assert_eq!(pool.stats(), (151, 150));
    }

    #[test]
    #[should_panic(expected = "already shut down")]
    fn submit_after_shutdown_panics() {
        let mut pool = ThreadPool::new(1, 1);
        pool.shutdown();
        pool.submit(|| {});
    }

    #[test]
    fn scoped_map_order_preserved() {
        let items: Vec<u64> = (0..257).collect();
        let out = scoped_map(items.clone(), 4, |x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn scoped_map_single_thread_path() {
        let out = scoped_map(vec![1, 2, 3], 1, |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn scoped_map_empty() {
        let out: Vec<u32> = scoped_map(Vec::<u32>::new(), 4, |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn scoped_map_more_threads_than_items() {
        let out = scoped_map(vec![5, 6], 16, |x| x * x);
        assert_eq!(out, vec![25, 36]);
    }
}
