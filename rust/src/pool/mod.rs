//! Worker-pool substrate (no rayon offline): a fixed set of threads pulling
//! boxed jobs from a bounded channel — the bound is the pipeline's
//! backpressure — plus a scoped map helper for data-parallel solver work.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size thread pool with a bounded queue. `submit` blocks when the
/// queue is full (backpressure), so producers can't outrun the workers.
pub struct ThreadPool {
    tx: Option<SyncSender<Job>>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
    submitted: Arc<AtomicUsize>,
    completed: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(threads: usize, queue_cap: usize) -> Self {
        let threads = threads.max(1);
        let (tx, rx) = sync_channel::<Job>(queue_cap.max(1));
        let rx = Arc::new(Mutex::new(rx));
        let submitted = Arc::new(AtomicUsize::new(0));
        let completed = Arc::new(AtomicUsize::new(0));
        let workers = (0..threads)
            .map(|i| {
                let rx: Arc<Mutex<Receiver<Job>>> = Arc::clone(&rx);
                let completed = Arc::clone(&completed);
                std::thread::Builder::new()
                    .name(format!("msb-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().expect("pool lock poisoned");
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                completed.fetch_add(1, Ordering::Release);
                            }
                            Err(_) => break, // sender dropped: shutdown
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, size: threads, submitted, completed }
    }

    /// Default pool: one worker per available core.
    pub fn with_default_size() -> Self {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        ThreadPool::new(n, n * 4)
    }

    /// Enqueue a job; blocks when the queue is at capacity.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        self.submitted.fetch_add(1, Ordering::Release);
        self.tx
            .as_ref()
            .expect("pool already shut down")
            .send(Box::new(job))
            .expect("workers gone");
    }

    /// Worker count the pool was built with (stable across shutdown).
    pub fn threads(&self) -> usize {
        self.size
    }

    /// `(submitted, completed)` job counts. After [`ThreadPool::shutdown`]
    /// the two are equal: the join synchronizes every completion.
    pub fn stats(&self) -> (usize, usize) {
        (
            self.submitted.load(Ordering::Acquire),
            self.completed.load(Ordering::Acquire),
        )
    }

    /// Drop the sender and join all workers (drains the queue first).
    /// Idempotent; the pool remains readable (`stats`) afterwards.
    pub fn shutdown(&mut self) {
        self.tx.take();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Data-parallel map over items using scoped threads: results keep input
/// order; panics propagate. For CPU-bound solver fan-out (quantizing many
/// layer matrices).
pub fn scoped_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Send + Sync,
{
    let threads = threads.max(1);
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    if threads == 1 || n == 1 {
        return items.into_iter().map(f).collect();
    }
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let work: Vec<(usize, T)> = items.into_iter().enumerate().collect();
    let queue = Mutex::new(work);
    let slots_mtx = Mutex::new(&mut slots);
    std::thread::scope(|s| {
        for _ in 0..threads.min(n) {
            s.spawn(|| loop {
                let item = queue.lock().expect("queue").pop();
                match item {
                    Some((i, t)) => {
                        let r = f(t);
                        slots_mtx.lock().expect("slots")[i] = Some(r);
                    }
                    None => break,
                }
            });
        }
    });
    slots.into_iter().map(|o| o.expect("scoped_map slot unfilled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let mut pool = ThreadPool::new(4, 8);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.shutdown();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn pool_stats() {
        let mut pool = ThreadPool::new(2, 4);
        assert_eq!(pool.threads(), 2);
        for _ in 0..10 {
            pool.submit(|| {});
        }
        pool.shutdown();
        // the join synchronizes: every submitted job is also completed
        assert_eq!(pool.stats(), (10, 10));
        // shutdown is idempotent and stats stay readable
        pool.shutdown();
        assert_eq!(pool.stats(), (10, 10));
        assert_eq!(pool.threads(), 2);
    }

    #[test]
    fn backpressure_blocks_but_completes() {
        // tiny queue, slow jobs: submit must block rather than drop
        let mut pool = ThreadPool::new(1, 1);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..20 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                std::thread::sleep(std::time::Duration::from_micros(200));
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.shutdown();
        assert_eq!(counter.load(Ordering::Relaxed), 20);
    }

    #[test]
    fn scoped_map_order_preserved() {
        let items: Vec<u64> = (0..257).collect();
        let out = scoped_map(items.clone(), 4, |x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn scoped_map_single_thread_path() {
        let out = scoped_map(vec![1, 2, 3], 1, |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn scoped_map_empty() {
        let out: Vec<u32> = scoped_map(Vec::<u32>::new(), 4, |x| x);
        assert!(out.is_empty());
    }
}
