//! Offline stub of the `xla` / PJRT bindings used by `msb_quant::runtime`.
//!
//! The real evaluation path compiles AOT-lowered HLO through a PJRT CPU
//! client (`xla_extension`). That native library is not present in the
//! offline build environment, so this crate provides the exact API surface
//! the runtime layer consumes with a constructor that fails cleanly:
//! [`PjRtClient::cpu`] returns [`Error::Unavailable`], and every caller in
//! the workspace already treats a failed client as "no PJRT here — skip".
//!
//! Replacing this stub with real bindings (e.g. a `xla-rs` build against
//! `xla_extension`) requires no changes in `msb_quant` — only the `xla`
//! dependency line in `rust/Cargo.toml`.

use std::fmt;

/// Stub error type mirroring `xla::Error`.
#[derive(Clone, Debug)]
pub enum Error {
    /// The PJRT runtime is not available in this build.
    Unavailable(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "{what}: PJRT is unavailable in this build (stub `xla` crate; \
                 link a real xla_extension to enable the runtime)"
            ),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &'static str) -> Result<T> {
    Err(Error::Unavailable(what))
}

/// Element types uploadable to device buffers.
pub trait NativeType: Copy {}

impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i8 {}
impl NativeType for i16 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}
impl NativeType for u16 {}
impl NativeType for u32 {}
impl NativeType for u64 {}

/// A PJRT device handle (never constructed by the stub).
#[derive(Debug)]
pub struct PjRtDevice {}

/// A PJRT client handle (never constructed by the stub).
#[derive(Debug)]
pub struct PjRtClient {}

impl PjRtClient {
    /// Always fails in the stub: there is no PJRT plugin to load.
    pub fn cpu() -> Result<Self> {
        unavailable("creating PJRT CPU client")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("compiling computation")
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        unavailable("uploading host buffer")
    }
}

/// Parsed HLO module (never constructed by the stub).
#[derive(Debug)]
pub struct HloModuleProto {}

impl HloModuleProto {
    /// Always fails in the stub; the text parser lives in xla_extension.
    pub fn from_text_file(_path: &str) -> Result<Self> {
        unavailable("parsing HLO text")
    }
}

/// An XLA computation wrapping a parsed HLO module.
#[derive(Debug)]
pub struct XlaComputation {}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation {}
    }
}

/// A compiled executable (never constructed by the stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("executing")
    }
}

/// A device buffer (never constructed by the stub).
#[derive(Debug)]
pub struct PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("downloading literal")
    }
}

/// A host literal (never constructed by the stub).
#[derive(Debug)]
pub struct Literal {}

impl Literal {
    pub fn to_tuple1(self) -> Result<Literal> {
        unavailable("unpacking tuple")
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable("reading literal")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_cleanly() {
        let err = PjRtClient::cpu().unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("PJRT is unavailable"), "{msg}");
    }

    #[test]
    fn hlo_parsing_fails_cleanly() {
        assert!(HloModuleProto::from_text_file("/tmp/x.hlo.txt").is_err());
    }

    #[test]
    fn error_is_std_error() {
        fn takes_std_error<E: std::error::Error + Send + Sync + 'static>(_e: E) {}
        takes_std_error(Error::Unavailable("x"));
    }
}
