//! Vendored, dependency-free subset of the `anyhow` API.
//!
//! The container this repo builds in has no crates.io access, so the crate
//! set must be fully offline. This shim implements exactly the surface the
//! workspace uses — [`Error`], [`Result`], the [`Context`] trait and the
//! `anyhow!` / `bail!` / `ensure!` macros — with string-based context
//! frames instead of `anyhow`'s type-erased backtrace machinery. Swapping
//! back to the real crate is a one-line `Cargo.toml` change; no call site
//! depends on anything beyond the real crate's semantics.

use std::fmt;

/// A string-chained error: `frames[0]` is the outermost context, the last
/// frame is the root cause.
pub struct Error {
    frames: Vec<String>,
}

impl Error {
    /// Create an error from a printable message (the `anyhow!` entry point).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { frames: vec![message.to_string()] }
    }

    fn push_context(mut self, context: impl fmt::Display) -> Self {
        self.frames.insert(0, context.to_string());
        self
    }

    /// The outermost message (what `{}` prints).
    pub fn root_message(&self) -> &str {
        &self.frames[0]
    }

    /// Iterate over the context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.frames.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` prints the whole chain, `anyhow`-style
            f.write_str(&self.frames.join(": "))
        } else {
            f.write_str(&self.frames[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.frames[0])?;
        if self.frames.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for frame in &self.frames[1..] {
                write!(f, "\n    {frame}")?;
            }
        }
        Ok(())
    }
}

// Like the real `anyhow::Error`, this type deliberately does NOT implement
// `std::error::Error`: the blanket conversion below would otherwise overlap
// with the reflexive `impl From<T> for T`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Self {
        let mut frames = vec![err.to_string()];
        let mut source = err.source();
        while let Some(cause) = source {
            frames.push(cause.to_string());
            source = cause.source();
        }
        Error { frames }
    }
}

/// Drop-in alias for `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (`Result`) or turn `None` into an error
/// (`Option`) — the `anyhow::Context` extension trait.
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: Into<Error>,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into().push_context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().push_context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/real/path")?;
        Ok(s)
    }

    #[test]
    fn from_std_error_via_question_mark() {
        let err = io_fail().unwrap_err();
        assert!(!err.root_message().is_empty());
    }

    #[test]
    fn context_layers_accumulate() {
        let err = io_fail().context("loading config").unwrap_err();
        assert_eq!(err.root_message(), "loading config");
        assert!(err.chain().count() >= 2);
        let full = format!("{err:#}");
        assert!(full.starts_with("loading config: "), "{full}");
    }

    #[test]
    fn with_context_is_lazy() {
        let ok: Result<u32, Error> = Ok(7);
        let v = ok.with_context(|| -> String { panic!("must not run") }).unwrap();
        assert_eq!(v, 7);
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        let err = none.context("missing value").unwrap_err();
        assert_eq!(err.to_string(), "missing value");
        assert_eq!(Some(3).context("unused").unwrap(), 3);
    }

    #[test]
    fn macros() {
        fn inner(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            if !flag {
                bail!("unreachable {}", 1);
            }
            Ok(1)
        }
        assert_eq!(inner(true).unwrap(), 1);
        assert_eq!(inner(false).unwrap_err().to_string(), "flag was false");
        let e = anyhow!("code {}", 42);
        assert_eq!(e.to_string(), "code 42");
    }

    #[test]
    fn debug_shows_cause_chain() {
        let err = io_fail().context("outer").unwrap_err();
        let dbg = format!("{err:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
    }
}
