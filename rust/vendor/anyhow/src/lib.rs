//! Vendored, dependency-free subset of the `anyhow` API.
//!
//! The container this repo builds in has no crates.io access, so the crate
//! set must be fully offline. This shim implements exactly the surface the
//! workspace uses — [`Error`], [`Result`], the [`Context`] trait,
//! [`Error::downcast_ref`] for typed-error recovery, and the
//! `anyhow!` / `bail!` / `ensure!` macros — with string-based context
//! frames instead of `anyhow`'s type-erased backtrace machinery. Swapping
//! back to the real crate is a one-line `Cargo.toml` change; no call site
//! depends on anything beyond the real crate's semantics.

use std::any::Any;
use std::fmt;

/// A string-chained error: `frames[0]` is the outermost context, the last
/// frame is the root cause. When built from a typed `std::error::Error`
/// (the `?` / `From` path), the original value rides along so
/// [`Error::downcast_ref`] can recover it through any context layers.
pub struct Error {
    frames: Vec<String>,
    payload: Option<Box<dyn Any + Send + Sync>>,
}

impl Error {
    /// Create an error from a printable message (the `anyhow!` entry point).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { frames: vec![message.to_string()], payload: None }
    }

    fn push_context(mut self, context: impl fmt::Display) -> Self {
        self.frames.insert(0, context.to_string());
        self
    }

    /// The outermost message (what `{}` prints).
    pub fn root_message(&self) -> &str {
        &self.frames[0]
    }

    /// Iterate over the context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.frames.iter().map(String::as_str)
    }

    /// The typed root cause, if this error was converted from a `T` via
    /// `?` / `From`. Context frames added later don't hide it — the same
    /// contract as the real crate's downcast through the cause chain.
    pub fn downcast_ref<T: Any>(&self) -> Option<&T> {
        self.payload.as_deref().and_then(|p| p.downcast_ref::<T>())
    }

    /// Whether the typed root cause is a `T` (see [`Error::downcast_ref`]).
    pub fn is<T: Any>(&self) -> bool {
        self.downcast_ref::<T>().is_some()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` prints the whole chain, `anyhow`-style
            f.write_str(&self.frames.join(": "))
        } else {
            f.write_str(&self.frames[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.frames[0])?;
        if self.frames.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for frame in &self.frames[1..] {
                write!(f, "\n    {frame}")?;
            }
        }
        Ok(())
    }
}

// Like the real `anyhow::Error`, this type deliberately does NOT implement
// `std::error::Error`: the blanket conversion below would otherwise overlap
// with the reflexive `impl From<T> for T`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Self {
        let mut frames = vec![err.to_string()];
        let mut source = err.source();
        while let Some(cause) = source {
            frames.push(cause.to_string());
            source = cause.source();
        }
        Error { frames, payload: Some(Box::new(err)) }
    }
}

/// Drop-in alias for `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (`Result`) or turn `None` into an error
/// (`Option`) — the `anyhow::Context` extension trait.
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: Into<Error>,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into().push_context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().push_context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/real/path")?;
        Ok(s)
    }

    #[test]
    fn from_std_error_via_question_mark() {
        let err = io_fail().unwrap_err();
        assert!(!err.root_message().is_empty());
    }

    #[derive(Debug, PartialEq)]
    struct Typed(u32);

    impl fmt::Display for Typed {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            write!(f, "typed error {}", self.0)
        }
    }

    impl std::error::Error for Typed {}

    #[test]
    fn downcast_ref_recovers_typed_root_cause() {
        let err = Error::from(Typed(7));
        assert!(err.is::<Typed>());
        assert_eq!(err.downcast_ref::<Typed>(), Some(&Typed(7)));
        assert!(err.downcast_ref::<std::io::Error>().is_none());

        // Context layers change what `{}` prints but not the typed root.
        let res: Result<()> = Err(err);
        let wrapped = res.context("outer").unwrap_err();
        assert_eq!(wrapped.root_message(), "outer");
        assert_eq!(wrapped.downcast_ref::<Typed>(), Some(&Typed(7)));

        // Message-built errors carry no typed payload.
        assert!(anyhow!("plain {}", 1).downcast_ref::<Typed>().is_none());
    }

    #[test]
    fn context_layers_accumulate() {
        let err = io_fail().context("loading config").unwrap_err();
        assert_eq!(err.root_message(), "loading config");
        assert!(err.chain().count() >= 2);
        let full = format!("{err:#}");
        assert!(full.starts_with("loading config: "), "{full}");
    }

    #[test]
    fn with_context_is_lazy() {
        let ok: Result<u32, Error> = Ok(7);
        let v = ok.with_context(|| -> String { panic!("must not run") }).unwrap();
        assert_eq!(v, 7);
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        let err = none.context("missing value").unwrap_err();
        assert_eq!(err.to_string(), "missing value");
        assert_eq!(Some(3).context("unused").unwrap(), 3);
    }

    #[test]
    fn macros() {
        fn inner(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            if !flag {
                bail!("unreachable {}", 1);
            }
            Ok(1)
        }
        assert_eq!(inner(true).unwrap(), 1);
        assert_eq!(inner(false).unwrap_err().to_string(), "flag was false");
        let e = anyhow!("code {}", 42);
        assert_eq!(e.to_string(), "code 42");
    }

    #[test]
    fn debug_shows_cause_chain() {
        let err = io_fail().context("outer").unwrap_err();
        let dbg = format!("{err:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
    }
}
