//! Figures 4 & 5 — quantization wall-clock vs matrix size on N(0,1)
//! instances: XNOR/BLOCKED-XNOR fastest, WGM orders faster than GG, DG
//! infeasible beyond small sizes.

use msb_quant::benchlib::{self, time_median};
use msb_quant::quant::{msb::MsbQuantizer, xnor::XnorQuantizer, QuantConfig, Quantizer};
use msb_quant::stats::Rng;
use msb_quant::tensor::Matrix;

fn main() {
    let cfg = QuantConfig::per_tensor(4).unwrap().no_bf16().with_lambda(0.0);
    let bcfg = QuantConfig::block_wise(4, 64).unwrap().no_bf16().with_lambda(0.0);

    benchlib::header("Fig 4 analog — small-matrix quantization time (s)");
    println!("n,dg,gg,wgm_w16,xnor,blocked_xnor");
    let small: Vec<usize> =
        if benchlib::fast_mode() { vec![8, 32] } else { vec![8, 16, 32, 64, 96, 128] };
    for n in small {
        let mut rng = Rng::new(3000 + n as u64);
        let w = Matrix::randn(n, n, &mut rng);
        let t_dg = time_median(3, || MsbQuantizer::dg().quantize(&w, &cfg));
        let t_gg = time_median(3, || MsbQuantizer::gg().quantize(&w, &cfg));
        let t_w = time_median(3, || {
            MsbQuantizer::wgm().quantize(&w, &cfg.clone().with_window(16).unwrap())
        });
        let t_x = time_median(3, || XnorQuantizer::whole().quantize(&w, &cfg));
        let t_b = time_median(3, || XnorQuantizer::blocked().quantize(&w, &bcfg));
        println!("{n},{t_dg:.5},{t_gg:.5},{t_w:.5},{t_x:.6},{t_b:.6}");
    }

    benchlib::header("Fig 5 analog — large-matrix quantization time (s); DG omitted");
    println!("n,gg,wgm_w64,wgm_lo,xnor,blocked_xnor");
    let large: Vec<usize> =
        if benchlib::fast_mode() { vec![256] } else { vec![256, 512, 1024, 2048] };
    for n in large {
        let mut rng = Rng::new(4000 + n as u64);
        let w = Matrix::randn(n, n, &mut rng);
        let t_gg = time_median(1, || MsbQuantizer::gg().quantize(&w, &cfg));
        let t_w = time_median(1, || {
            MsbQuantizer::wgm().quantize(&w, &cfg.clone().with_window(64).unwrap())
        });
        let t_lo = time_median(1, || MsbQuantizer::wgm_lo().quantize(&w, &cfg));
        let t_x = time_median(3, || XnorQuantizer::whole().quantize(&w, &cfg));
        let t_b = time_median(3, || XnorQuantizer::blocked().quantize(&w, &bcfg));
        println!("{n},{t_gg:.4},{t_w:.4},{t_lo:.4},{t_x:.5},{t_b:.5}");
    }
    println!("\npaper shape: time(gg) ≫ time(wgm) ≥ time(wgm-lo) ≫ time(xnor).");
}
