//! §Perf — solver hot-path throughput + the lazy-invalidation ablation
//! (DESIGN.md "Design choices" #2). Reports elements/second for the
//! production paths and compares the generation-counter heap against a
//! naive rebuild-the-heap merger.

use std::collections::BTreeMap;

use msb_quant::benchlib::{self, time_median};
use msb_quant::msb::{Algo, CostParams, Grouping, Prefix, Solver, SortedMags};
use msb_quant::quant::{msb::MsbQuantizer, QuantConfig, Quantizer};
use msb_quant::stats::Rng;
use msb_quant::tensor::Matrix;

/// Naive ablation: adjacent merging with a fully re-scanned cost array per
/// step (no heap, no lazy invalidation) — O(g²) merges.
fn naive_merge(prefix: &Prefix, target: usize, params: &CostParams) -> Grouping {
    let n = prefix.len();
    let mut bounds: Vec<usize> = (1..=n).collect();
    while bounds.len() > target {
        let mut best = (f64::INFINITY, 0usize);
        let mut start = 0usize;
        for k in 0..bounds.len() - 1 {
            let (a, b, c) = (start, bounds[k], bounds[k + 1]);
            let delta = prefix.cost(a, c, params)
                - prefix.cost(a, b, params)
                - prefix.cost(b, c, params);
            if delta < best.0 {
                best = (delta, k);
            }
            start = bounds[k];
        }
        bounds.remove(best.1);
    }
    Grouping::new(bounds)
}

fn main() {
    let fast = benchlib::fast_mode();
    let mut results: BTreeMap<String, f64> = BTreeMap::new();

    // --- production per-tensor path -------------------------------------
    let n = if fast { 1 << 16 } else { 1 << 22 }; // 4M elements ≈ a 2048x2048 layer
    let mut rng = Rng::new(1);
    let mut vals = vec![0.0f32; n];
    rng.fill_normal(&mut vals, 1.0);
    benchlib::header(&format!("solver throughput (n = {n})"));
    for (name, algo, groups) in [
        ("wgm w=64 g=32 (paper per-tensor)", Algo::Wgm { window: 64 }, 32),
        ("wgm w=256 g=256", Algo::Wgm { window: 256 }, 256),
        (
            "wgm-lo (256 bins)",
            Algo::WgmLo { bins: 256, range: 32, max_iters: 12, patience: 3 },
            32,
        ),
    ] {
        let solver = Solver::new(algo).with_lambda(0.75);
        let t = time_median(if fast { 1 } else { 3 }, || solver.quantize(&vals, groups));
        let meps = n as f64 / t / 1e6;
        println!("  {name:<36} {t:>8.3} s   {meps:>8.2} Melem/s");
        results.insert(name.into(), meps);
    }

    // --- production block-wise path --------------------------------------
    let dim = if fast { 256 } else { 2048 };
    let w = Matrix::weightlike(dim, dim, &mut rng);
    let cfg = QuantConfig::block_wise(4, 64).with_window(1).no_bf16();
    let t = time_median(if fast { 1 } else { 3 }, || MsbQuantizer::wgm().quantize(&w, &cfg));
    println!(
        "  {:<36} {t:>8.3} s   {:>8.2} Melem/s",
        format!("block-wise wgm t=64 ({dim}x{dim})"),
        w.len() as f64 / t / 1e6
    );

    // --- lazy invalidation ablation --------------------------------------
    let n2 = if fast { 2_000 } else { 20_000 };
    let mut small = vec![0.0f32; n2];
    rng.fill_normal(&mut small, 1.0);
    let sm = SortedMags::from_values(&small);
    let prefix = Prefix::new(&sm.mags);
    let params = CostParams::unnormalized(0.0);
    benchlib::header(&format!("lazy-invalidation ablation (n = {n2}, g = 16)"));
    let t_heap = time_median(3, || {
        Solver::new(Algo::Gg).with_lambda(0.0).solve_sorted(&sm, 16)
    });
    let t_naive = time_median(if fast { 1 } else { 1 }, || naive_merge(&prefix, 16, &params));
    // equivalence of result quality
    let g_heap = Solver::new(Algo::Gg).with_lambda(0.0).solve_sorted(&sm, 16);
    let g_naive = naive_merge(&prefix, 16, &params);
    println!(
        "  heap+lazy {t_heap:>8.4} s | naive rescan {t_naive:>8.4} s | speedup {:>6.1}x",
        t_naive / t_heap
    );
    println!(
        "  sse heap {:.4} vs naive {:.4} (same greedy, same answer modulo ties)",
        g_heap.sse(&prefix),
        g_naive.sse(&prefix)
    );
    assert!(t_heap < t_naive, "lazy heap must beat O(g^2) rescan");
}
