//! §Perf — solver hot-path throughput, the block-engine method grid, and
//! the lazy-invalidation ablation (DESIGN.md "Design choices" #2). Reports
//! elements/second for the production paths, blocks/second per engine
//! method (serial and pooled), and compares the generation-counter heap
//! against a naive rebuild-the-heap merger.
//!
//! Machine-readable output: `BENCH_perf.json` (method → blocks/sec via
//! `benchlib::write_bench_json`), uploaded as a CI artifact so the repo's
//! perf trajectory accumulates.

use std::collections::BTreeMap;

use msb_quant::benchlib::{self, time_median};
use msb_quant::msb::{Algo, CostParams, Grouping, Prefix, Solver, SortedMags};
use msb_quant::pool::ThreadPool;
use msb_quant::quant::{calibration_free_zoo, msb::MsbQuantizer, QuantConfig, Quantizer};
use msb_quant::stats::Rng;
use msb_quant::tensor::Matrix;

/// Naive ablation: adjacent merging with a fully re-scanned cost array per
/// step (no heap, no lazy invalidation) — O(g²) merges.
fn naive_merge(prefix: &Prefix, target: usize, params: &CostParams) -> Grouping {
    let n = prefix.len();
    let mut bounds: Vec<usize> = (1..=n).collect();
    while bounds.len() > target {
        let mut best = (f64::INFINITY, 0usize);
        let mut start = 0usize;
        for k in 0..bounds.len() - 1 {
            let (a, b, c) = (start, bounds[k], bounds[k + 1]);
            let delta = prefix.cost(a, c, params)
                - prefix.cost(a, b, params)
                - prefix.cost(b, c, params);
            if delta < best.0 {
                best = (delta, k);
            }
            start = bounds[k];
        }
        bounds.remove(best.1);
    }
    Grouping::new(bounds)
}

fn main() {
    let fast = benchlib::fast_mode();
    // method → blocks/sec, persisted to BENCH_perf.json at the end
    let mut results: BTreeMap<String, f64> = BTreeMap::new();

    // --- production per-tensor path -------------------------------------
    let n = if fast { 1 << 16 } else { 1 << 22 }; // 4M elements ≈ a 2048x2048 layer
    let mut rng = Rng::new(1);
    let mut vals = vec![0.0f32; n];
    rng.fill_normal(&mut vals, 1.0);
    benchlib::header(&format!("solver throughput (n = {n})"));
    for (name, algo, groups) in [
        ("wgm w=64 g=32 (paper per-tensor)", Algo::Wgm { window: 64 }, 32),
        ("wgm w=256 g=256", Algo::Wgm { window: 256 }, 256),
        (
            "wgm-lo (256 bins)",
            Algo::WgmLo { bins: 256, range: 32, max_iters: 12, patience: 3 },
            32,
        ),
    ] {
        let solver = Solver::new(algo).with_lambda(0.75);
        let t = time_median(if fast { 1 } else { 3 }, || solver.quantize(&vals, groups));
        let meps = n as f64 / t / 1e6;
        println!("  {name:<36} {t:>8.3} s   {meps:>8.2} Melem/s");
    }

    // --- engine block throughput: the method grid ------------------------
    let dim = if fast { 256 } else { 2048 };
    let w = Matrix::weightlike(dim, dim, &mut rng);
    let cfg = QuantConfig::block_wise(4, 64).with_window(1).no_bf16();
    let n_blocks = (w.len() / 64) as f64;
    let reps = if fast { 1 } else { 3 };
    benchlib::header(&format!("engine block throughput ({dim}x{dim}, t=64, serial)"));
    for q in calibration_free_zoo() {
        let t = time_median(reps, || q.quantize(&w, &cfg));
        let bps = n_blocks / t;
        println!("  {:<36} {t:>8.3} s   {bps:>12.0} blocks/s", q.name());
        results.insert(q.name().to_string(), bps);
    }

    // --- intra-layer parallelism: tiles on the shared pool ---------------
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut pool = ThreadPool::new(threads, threads * 4);
    benchlib::header(&format!("engine block throughput (pooled, {threads} workers)"));
    let wgm = MsbQuantizer::wgm();
    let t_pooled = time_median(reps, || wgm.quantize_with_pool(&w, &cfg, &pool));
    pool.shutdown();
    let bps_pooled = n_blocks / t_pooled;
    // serial msb-wgm blocks/sec was measured in the zoo loop above
    let speedup = bps_pooled / results["msb-wgm"];
    println!(
        "  {:<36} {t_pooled:>8.3} s   {bps_pooled:>12.0} blocks/s ({speedup:.2}x vs serial)",
        "msb-wgm pooled"
    );
    results.insert("msb-wgm-pooled".to_string(), bps_pooled);

    // --- lazy invalidation ablation --------------------------------------
    let n2 = if fast { 2_000 } else { 20_000 };
    let mut small = vec![0.0f32; n2];
    rng.fill_normal(&mut small, 1.0);
    let sm = SortedMags::from_values(&small);
    let prefix = Prefix::new(&sm.mags);
    let params = CostParams::unnormalized(0.0);
    benchlib::header(&format!("lazy-invalidation ablation (n = {n2}, g = 16)"));
    let t_heap = time_median(3, || {
        Solver::new(Algo::Gg).with_lambda(0.0).solve_sorted(&sm, 16)
    });
    let t_naive = time_median(if fast { 1 } else { 1 }, || naive_merge(&prefix, 16, &params));
    // equivalence of result quality
    let g_heap = Solver::new(Algo::Gg).with_lambda(0.0).solve_sorted(&sm, 16);
    let g_naive = naive_merge(&prefix, 16, &params);
    println!(
        "  heap+lazy {t_heap:>8.4} s | naive rescan {t_naive:>8.4} s | speedup {:>6.1}x",
        t_naive / t_heap
    );
    println!(
        "  sse heap {:.4} vs naive {:.4} (same greedy, same answer modulo ties)",
        g_heap.sse(&prefix),
        g_naive.sse(&prefix)
    );
    assert!(t_heap < t_naive, "lazy heap must beat O(g^2) rescan");

    // --- machine-readable output -----------------------------------------
    match benchlib::write_bench_json("perf", &results) {
        Ok(path) => println!("\nwrote {} ({} methods)", path.display(), results.len()),
        Err(e) => eprintln!("\nBENCH_perf.json not written: {e}"),
    }
}
