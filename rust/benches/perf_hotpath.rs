//! §Perf — solver hot-path throughput, the block-engine method grid, the
//! scan-vs-heap merge-kernel ablation, and the lazy-invalidation ablation
//! (DESIGN.md "Design choices" #2). Reports elements/second for the
//! production paths, blocks/second per engine method (serial and pooled),
//! and compares the merge kernels on the block-wise hot-path instance
//! shape (64 singletons → 8 groups) — the scan kernel must win there,
//! asserted below.
//!
//! Machine-readable output: `BENCH_perf.json` (method → blocks/sec via
//! `benchlib::merge_bench_json`, shared with the `table3_quant_time`
//! scheduler arm), uploaded as a CI artifact so the repo's perf
//! trajectory accumulates.

use std::collections::BTreeMap;

use msb_quant::benchlib::{self, time_median};
use msb_quant::msb::gg::{greedy_merge_ws_kernel, MergeKernel, MergeWorkspace};
use msb_quant::msb::{Algo, CostParams, Grouping, Prefix, Solver, SortedMags};
use msb_quant::pool::ThreadPool;
use msb_quant::quant::{calibration_free_zoo, msb::MsbQuantizer, QuantConfig, Quantizer};
use msb_quant::stats::Rng;
use msb_quant::tensor::Matrix;

/// Naive ablation: adjacent merging with a fully re-scanned cost array per
/// step (no heap, no lazy invalidation) — O(g²) merges.
fn naive_merge(prefix: &Prefix, target: usize, params: &CostParams) -> Grouping {
    let n = prefix.len();
    let mut bounds: Vec<usize> = (1..=n).collect();
    while bounds.len() > target {
        let mut best = (f64::INFINITY, 0usize);
        let mut start = 0usize;
        for k in 0..bounds.len() - 1 {
            let (a, b, c) = (start, bounds[k], bounds[k + 1]);
            let delta = prefix.cost(a, c, params)
                - prefix.cost(a, b, params)
                - prefix.cost(b, c, params);
            if delta < best.0 {
                best = (delta, k);
            }
            start = bounds[k];
        }
        bounds.remove(best.1);
    }
    Grouping::new(bounds)
}

fn main() {
    let fast = benchlib::fast_mode();
    // method → blocks/sec, persisted to BENCH_perf.json at the end
    let mut results: BTreeMap<String, f64> = BTreeMap::new();

    // --- production per-tensor path -------------------------------------
    let n = if fast { 1 << 16 } else { 1 << 22 }; // 4M elements ≈ a 2048x2048 layer
    let mut rng = Rng::new(1);
    let mut vals = vec![0.0f32; n];
    rng.fill_normal(&mut vals, 1.0);
    benchlib::header(&format!("solver throughput (n = {n})"));
    for (name, algo, groups) in [
        ("wgm w=64 g=32 (paper per-tensor)", Algo::Wgm { window: 64 }, 32),
        ("wgm w=256 g=256", Algo::Wgm { window: 256 }, 256),
        (
            "wgm-lo (256 bins)",
            Algo::WgmLo { bins: 256, range: 32, max_iters: 12, patience: 3 },
            32,
        ),
    ] {
        let solver = Solver::new(algo).with_lambda(0.75);
        let t = time_median(if fast { 1 } else { 3 }, || solver.quantize(&vals, groups));
        let meps = n as f64 / t / 1e6;
        println!("  {name:<36} {t:>8.3} s   {meps:>8.2} Melem/s");
    }

    // --- engine block throughput: the method grid ------------------------
    let dim = if fast { 256 } else { 2048 };
    let w = Matrix::weightlike(dim, dim, &mut rng);
    let cfg = QuantConfig::block_wise(4, 64).unwrap().with_window(1).unwrap().no_bf16();
    let n_blocks = (w.len() / 64) as f64;
    let reps = if fast { 1 } else { 3 };
    benchlib::header(&format!("engine block throughput ({dim}x{dim}, t=64, serial)"));
    for q in calibration_free_zoo() {
        let t = time_median(reps, || q.quantize(&w, &cfg));
        let bps = n_blocks / t;
        println!("  {:<36} {t:>8.3} s   {bps:>12.0} blocks/s", q.name());
        results.insert(q.name().to_string(), bps);
    }

    // --- intra-layer parallelism: tiles on the shared pool ---------------
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut pool = ThreadPool::new(threads, threads * 4);
    benchlib::header(&format!("engine block throughput (pooled, {threads} workers)"));
    let wgm = MsbQuantizer::wgm();
    let t_pooled = time_median(reps, || wgm.quantize_with_pool(&w, &cfg, &pool));
    pool.shutdown();
    let bps_pooled = n_blocks / t_pooled;
    // serial msb-wgm blocks/sec was measured in the zoo loop above
    let speedup = bps_pooled / results["msb-wgm"];
    println!(
        "  {:<36} {t_pooled:>8.3} s   {bps_pooled:>12.0} blocks/s ({speedup:.2}x vs serial)",
        "msb-wgm pooled"
    );
    results.insert("msb-wgm-pooled".to_string(), bps_pooled);

    // --- merge kernel ablation: scan vs heap on 64-element blocks --------
    // The block-wise hot path merges ≤64 singletons down to 8 per block;
    // the flat argmin scan must beat heap push/pop + stale-skip there.
    let n_insts = if fast { 2048 } else { 8192 };
    let mut prefixes: Vec<Prefix> = Vec::with_capacity(n_insts);
    let mut blk = vec![0.0f32; 64];
    for _ in 0..n_insts {
        rng.fill_normal(&mut blk, 1.0);
        let sm = SortedMags::from_values(&blk);
        prefixes.push(Prefix::new(&sm.mags));
    }
    let merge_params = CostParams::unnormalized(0.0);
    benchlib::header(&format!(
        "merge kernel ablation ({n_insts} x 64-singleton blocks -> 8 groups)"
    ));
    let mut merge_times = BTreeMap::new();
    for (label, kernel) in [("scan", MergeKernel::Scan), ("heap", MergeKernel::Heap)] {
        let mut ws = MergeWorkspace::default();
        let mut bounds = Vec::new();
        let t = time_median(5, || {
            for p in &prefixes {
                let n = p.len();
                greedy_merge_ws_kernel(
                    &mut ws,
                    p,
                    (0..n).map(|i| (i, i + 1)),
                    8,
                    &merge_params,
                    &mut bounds,
                    kernel,
                );
            }
        });
        let bps = n_insts as f64 / t;
        println!("  merge-{label:<30} {t:>8.4} s   {bps:>12.0} blocks/s");
        results.insert(format!("merge-{label}-64-bps"), bps);
        merge_times.insert(label.to_string(), t);
    }
    // golden equivalence on a few instances, then the headline claim
    {
        let mut ws = MergeWorkspace::default();
        for p in prefixes.iter().take(32) {
            let n = p.len();
            let (mut a, mut b) = (Vec::new(), Vec::new());
            greedy_merge_ws_kernel(
                &mut ws,
                p,
                (0..n).map(|i| (i, i + 1)),
                8,
                &merge_params,
                &mut a,
                MergeKernel::Scan,
            );
            greedy_merge_ws_kernel(
                &mut ws,
                p,
                (0..n).map(|i| (i, i + 1)),
                8,
                &merge_params,
                &mut b,
                MergeKernel::Heap,
            );
            assert_eq!(a, b, "merge kernels must produce identical groupings");
        }
    }
    let speedup = merge_times["heap"] / merge_times["scan"];
    println!("  scan speedup over heap: {speedup:.2}x");
    assert!(
        merge_times["scan"] < merge_times["heap"],
        "scan kernel must beat the heap on 64-element block instances \
         ({:.4}s vs {:.4}s)",
        merge_times["scan"],
        merge_times["heap"]
    );

    // --- lazy invalidation ablation --------------------------------------
    let n2 = if fast { 2_000 } else { 20_000 };
    let mut small = vec![0.0f32; n2];
    rng.fill_normal(&mut small, 1.0);
    let sm = SortedMags::from_values(&small);
    let prefix = Prefix::new(&sm.mags);
    let params = CostParams::unnormalized(0.0);
    benchlib::header(&format!("lazy-invalidation ablation (n = {n2}, g = 16)"));
    let t_heap = time_median(3, || {
        Solver::new(Algo::Gg).with_lambda(0.0).solve_sorted(&sm, 16)
    });
    let t_naive = time_median(if fast { 1 } else { 1 }, || naive_merge(&prefix, 16, &params));
    // equivalence of result quality
    let g_heap = Solver::new(Algo::Gg).with_lambda(0.0).solve_sorted(&sm, 16);
    let g_naive = naive_merge(&prefix, 16, &params);
    println!(
        "  heap+lazy {t_heap:>8.4} s | naive rescan {t_naive:>8.4} s | speedup {:>6.1}x",
        t_naive / t_heap
    );
    println!(
        "  sse heap {:.4} vs naive {:.4} (same greedy, same answer modulo ties)",
        g_heap.sse(&prefix),
        g_naive.sse(&prefix)
    );
    assert!(t_heap < t_naive, "lazy heap must beat O(g^2) rescan");

    // --- machine-readable output -----------------------------------------
    // merge (not overwrite): the table3 scheduler arm shares this file
    match benchlib::merge_bench_json("perf", "perf_hotpath", &results) {
        Ok(path) => println!("\nwrote {} ({} keys)", path.display(), results.len()),
        Err(e) => eprintln!("\nBENCH_perf.json not written: {e}"),
    }
}
