//! Figure 6 — MSE vs λ̃ for GG and WGM on a 512×512 N(0,1) matrix: λ has
//! no practical effect when the group count is fixed externally (the
//! paper's negative result, reproduced).

use msb_quant::benchlib;
use msb_quant::msb::{lambda, SortedMags};
use msb_quant::quant::{msb::MsbQuantizer, QuantConfig, Quantizer};
use msb_quant::stats::Rng;
use msb_quant::tensor::Matrix;

fn main() {
    let n = if benchlib::fast_mode() { 128 } else { 512 };
    let mut rng = Rng::new(6);
    let w = Matrix::randn(n, n, &mut rng);
    let sm = SortedMags::from_values(&w.data);

    benchlib::header(&format!("Fig 6 analog — MSE vs λ̃ ({n}x{n}, per-tensor g=8)"));
    println!("lambda_tilde,gg,wgm_w64");
    let steps = if benchlib::fast_mode() { 3 } else { 11 };
    let mut series: Vec<(f64, f64)> = Vec::new();
    for i in 0..steps {
        let tilde = i as f64 / (steps - 1) as f64;
        let lam = lambda::lambda_of(tilde, &sm.mags);
        let cfg = QuantConfig::per_tensor(4).unwrap().no_bf16().with_lambda(lam);
        let gg = MsbQuantizer::gg().quantize(&w, &cfg).mse(&w);
        let wgm = MsbQuantizer::wgm()
            .quantize(&w, &cfg.clone().with_window(64).unwrap())
            .mse(&w);
        println!("{tilde:.2},{gg:.5},{wgm:.5}");
        series.push((gg, wgm));
    }
    let spread = |sel: fn(&(f64, f64)) -> f64| {
        let vals: Vec<f64> = series.iter().map(sel).collect();
        (vals.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
            - vals.iter().cloned().fold(f64::INFINITY, f64::min))
            / vals[0]
    };
    println!(
        "\nrelative MSE spread over λ̃: gg {:.2}%, wgm {:.2}% — paper shape: ≈ flat.",
        spread(|s| s.0) * 100.0,
        spread(|s| s.1) * 100.0
    );
}
