//! §Perf — the packed-payload pipeline: pack emission overhead on the
//! quantize path, packed-bytes ratio vs f32, and decode throughput
//! (serial vs pooled) for the engine method grid at the paper's 4-bit
//! t=64 setting. Self-asserting: every decode is checked bit-identical
//! to the simulated dequant before its timing is reported.
//!
//! Machine-readable output: `BENCH_pack.json` (`<method>-pack-bps`,
//! `<method>-decode-bps`, `<method>-packed-ratio`, plus
//! `msb-wgm-decode-pooled-bps`) via `benchlib::write_bench_json`,
//! uploaded as a CI artifact alongside `BENCH_perf.json`.

use std::collections::BTreeMap;
use std::sync::Arc;

use msb_quant::benchlib::{self, time_median};
use msb_quant::pool::ThreadPool;
use msb_quant::quant::engine::{decode_packed, BlockQuantizer};
use msb_quant::quant::hqq::HqqQuantizer;
use msb_quant::quant::msb::MsbQuantizer;
use msb_quant::quant::nf4::Nf4Quantizer;
use msb_quant::quant::rtn::RtnQuantizer;
use msb_quant::quant::xnor::XnorQuantizer;
use msb_quant::quant::{QuantConfig, Quantizer};

fn main() {
    let fast = benchlib::fast_mode();
    let mut results: BTreeMap<String, f64> = BTreeMap::new();

    let dim = if fast { 256 } else { 2048 };
    let reps = if fast { 1 } else { 3 };
    let w = benchlib::proxy_matrix(dim, dim);
    let cfg = QuantConfig::block_wise(4, 64).unwrap().with_window(1).unwrap().with_packed();
    let n_blocks = (w.len() / 64) as f64;
    let f32_bytes = (w.len() * 4) as f64;

    let methods: Vec<Arc<dyn BlockQuantizer>> = vec![
        Arc::new(RtnQuantizer::symmetric()),
        Arc::new(Nf4Quantizer::nf4()),
        Arc::new(HqqQuantizer::default()),
        Arc::new(XnorQuantizer::blocked()),
        Arc::new(MsbQuantizer::wgm()),
    ];

    benchlib::header(&format!("pack + decode throughput ({dim}x{dim}, t=64, serial)"));
    for q in &methods {
        let name = q.name().to_string();
        // quantize with payload emission (the pack path)
        let t_pack = time_median(reps, || {
            msb_quant::quant::engine::quantize_serial(&**q, &w, &cfg)
        });
        let qt = msb_quant::quant::engine::quantize_serial(&**q, &w, &cfg);
        let pt = qt.packed.clone().expect("packed payload");
        let ratio = pt.payload_bytes() as f64 / f32_bytes;

        // decode must reproduce the simulated dequant bit-for-bit
        let dec = decode_packed(Arc::clone(q), &pt, None);
        assert_eq!(dec.data, qt.dequant.data, "{name}: decode != simulated dequant");
        let t_dec = time_median(reps, || decode_packed(Arc::clone(q), &pt, None));

        let (pack_bps, dec_bps) = (n_blocks / t_pack, n_blocks / t_dec);
        println!(
            "  {name:<16} pack {t_pack:>8.3} s ({pack_bps:>12.0} blk/s)   \
             decode {t_dec:>8.4} s ({dec_bps:>12.0} blk/s)   {:.4}x of f32",
            ratio
        );
        results.insert(format!("{name}-pack-bps"), pack_bps);
        results.insert(format!("{name}-decode-bps"), dec_bps);
        results.insert(format!("{name}-packed-ratio"), ratio);
    }

    // --- pooled decode: the serving boot path ----------------------------
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut pool = ThreadPool::new(threads, threads * 4);
    let wgm: Arc<dyn BlockQuantizer> = Arc::new(MsbQuantizer::wgm());
    let qt = MsbQuantizer::wgm().quantize(&w, &cfg);
    let pt = qt.packed.expect("packed payload");
    let dec = decode_packed(Arc::clone(&wgm), &pt, Some(&pool));
    assert_eq!(dec.data, qt.dequant.data, "pooled decode != simulated dequant");
    let t_pooled = time_median(reps, || decode_packed(Arc::clone(&wgm), &pt, Some(&pool)));
    pool.shutdown();
    let bps = n_blocks / t_pooled;
    let speedup = bps / results["msb-wgm-decode-bps"];
    benchlib::header(&format!("pooled decode ({threads} workers)"));
    println!("  msb-wgm          {t_pooled:>8.4} s ({bps:>12.0} blk/s, {speedup:.2}x vs serial)");
    results.insert("msb-wgm-decode-pooled-bps".to_string(), bps);

    match benchlib::write_bench_json("pack", &results) {
        Ok(path) => println!("\nwrote {} ({} keys)", path.display(), results.len()),
        Err(e) => eprintln!("\nBENCH_pack.json not written: {e}"),
    }
}
