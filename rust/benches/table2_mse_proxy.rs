//! Table 2 — quantization MSE + wall-clock proxy on the "first linear
//! weight" instance: RTN / HQQ / WGM at per-tensor 4–6 bits and block-wise
//! 2–4 bits (t=64). Expected shape: WGM lowest MSE by a wide margin,
//! highest time; RTN fastest.

use msb_quant::benchlib::{self, time_once};
use msb_quant::quant::{
    hqq::HqqQuantizer, msb::MsbQuantizer, rtn::RtnQuantizer, QuantConfig, Quantizer,
};

fn main() {
    let dim = if benchlib::fast_mode() { 256 } else { 2048 };
    let w = benchlib::proxy_matrix(dim, dim);
    benchlib::header(&format!("Table 2 analog — proxy matrix {dim}x{dim}"));
    println!(
        "{}",
        benchlib::row(&["method", "setting", "bits", "time (s)", "MSE"]
            .map(String::from))
    );

    let methods: Vec<(&str, Box<dyn Quantizer>)> = vec![
        ("rtn", Box::new(RtnQuantizer::symmetric())),
        ("hqq", Box::new(HqqQuantizer::default())),
        ("wgm", Box::new(MsbQuantizer::wgm())),
    ];

    for (name, q) in &methods {
        for bits in [6u32, 5, 4] {
            let cfg = QuantConfig::per_tensor(bits).unwrap().with_window(64).unwrap();
            let (qt, dt) = time_once(|| q.quantize(&w, &cfg));
            println!(
                "{}",
                benchlib::row(&[
                    name.to_string(),
                    "per-tensor".into(),
                    bits.to_string(),
                    benchlib::fmt_f(dt, 3),
                    benchlib::fmt_f(qt.mse(&w), 3),
                ])
            );
        }
    }
    println!();
    for (name, q) in &methods {
        for bits in [4u32, 3, 2] {
            let cfg = QuantConfig::block_wise(bits, 64).unwrap().with_window(1).unwrap();
            let (qt, dt) = time_once(|| q.quantize(&w, &cfg));
            println!(
                "{}",
                benchlib::row(&[
                    name.to_string(),
                    "block-64".into(),
                    bits.to_string(),
                    benchlib::fmt_f(dt, 3),
                    benchlib::fmt_f(qt.mse(&w), 3),
                ])
            );
        }
    }
    println!("\npaper shape: WGM MSE ≪ HQQ < RTN at every bit-width; WGM slowest.");
}
