//! §Perf — self-speculative greedy decode (`server::draft` prompt-lookup
//! drafter + multi-token verify chunks through `ForwardModel::step_batch`
//! with page-level KV rollback).
//!
//! The claims under test:
//!
//! * speculative generation is *bit-identical* to plain chunked greedy
//!   decode and to solo token-by-token greedy decode, across MAC modes
//!   (f32, int8), dot kernels (scalar, detected SIMD), and thread counts
//!   (1, 4) — verification accepts exactly the prefix whose argmax chain
//!   matches, so a rejected draft can never leak into the output;
//! * on a workload with recurring suffixes the drafter provably accepts
//!   (checked by an exact scheduler mirror), speculative decode takes
//!   *strictly fewer* `step_batch` calls than plain decode — every
//!   accepted token is a whole forward step saved;
//! * the KV arena's speculative high-water mark stays within
//!   `ceil(draft_len / page_tokens)` pages per stream of the plain peak:
//!   rejected tails are truncated back and their pages recycled.
//!
//! All three are hard asserts: no number is reported from a run that
//! fails them. Results merge into `BENCH_perf.json` (`spec-*` keys)
//! next to the engine/scheduler/gemv/forward/serve numbers.

use std::collections::BTreeMap;
use std::time::Duration;

use msb_quant::benchlib::{self, time_median};
use msb_quant::forward::{argmax_row, synth, ForwardModel, ForwardSpec};
use msb_quant::kernels::{Kernel, MacMode};
use msb_quant::pipeline::{quantize, QuantizeOptions};
use msb_quant::quant::registry::Method;
use msb_quant::quant::QuantConfig;
use msb_quant::server::draft::{Drafter, DEFAULT_NGRAM};
use msb_quant::server::{BatchConfig, EvalServer, ServerStats};

/// Ground-truth greedy decode: solo `step` calls, one token at a time,
/// sharing the scheduler's argmax and budget-clamping rules.
fn solo_greedy(model: &ForwardModel, prompt: &[i32], max_new: usize) -> Vec<i32> {
    let (seq, vocab) = (model.spec().seq, model.spec().vocab);
    let mut toks = prompt.to_vec();
    toks.truncate(seq);
    assert!(!toks.is_empty() && max_new > 0);
    let eff = max_new.min(seq - toks.len() + 1);
    let mut kv = model.kv_state();
    let mut row = model.step(&mut kv, &toks).expect("prefill");
    let mut out = Vec::with_capacity(eff);
    loop {
        let next = argmax_row(&row[row.len() - vocab..]) as i32;
        out.push(next);
        if out.len() == eff {
            return out;
        }
        row = model.step(&mut kv, &[next]).expect("decode step");
    }
}

/// Exact mirror of the single-stream speculative schedule: given the
/// known greedy continuation `gen`, replay the scheduler's drafter state,
/// chunk caps and adaptive draft length to predict its `step_batch`
/// count and drafted/accepted totals. Valid for any single-job run (the
/// stream never shares a step, so no chunk lift occurs).
fn simulate_single_stream(
    prompt: &[i32],
    gen: &[i32],
    seq: usize,
    chunk: usize,
    draft_cap: usize,
) -> (u64, u64, u64) {
    let mut d = Drafter::new(DEFAULT_NGRAM);
    d.extend(prompt);
    let eff = gen.len();
    let mut fed = prompt.len();
    let mut steps = prompt.len().div_ceil(chunk) as u64;
    let mut c = 0usize;
    let mut draft_len = draft_cap;
    let (mut drafted, mut accepted) = (0u64, 0u64);
    loop {
        d.extend(&gen[c..=c]);
        c += 1;
        if c >= eff {
            return (steps, drafted, accepted);
        }
        let cap = draft_len.min(chunk.saturating_sub(1)).min(eff - c).min(seq - fed - 1);
        let prop = d.propose(cap);
        let k = prop.len();
        let j = prop.iter().zip(&gen[c..]).take_while(|(a, b)| a == b).count();
        drafted += k as u64;
        accepted += j as u64;
        d.extend(&gen[c..c + j]);
        c += j;
        if k > 0 {
            draft_len =
                if j == k { (draft_len + 1).min(draft_cap) } else { (draft_len / 2).max(1) };
        }
        fed += 1 + j;
        steps += 1;
        if c >= eff {
            return (steps, drafted, accepted);
        }
    }
}

/// Scan deterministic candidate prompts until the exact simulation
/// predicts at least one accepted draft token under this model — a
/// repetitive-suffix workload where speculation provably wins. The panic
/// is a loud fixture failure, never a flake (everything is deterministic).
fn find_accepting_workload(
    model: &ForwardModel,
    chunk: usize,
    draft_cap: usize,
    max_new: usize,
) -> (Vec<i32>, Vec<i32>, (u64, u64, u64)) {
    let fs = model.spec();
    for seed in 0..32u64 {
        let plen = 4 + (seed as usize % 5);
        let mut prompt = synth::synth_tokens(fs, plen, 17 + seed);
        if seed % 2 == 1 {
            let copy = prompt.clone();
            prompt.extend_from_slice(&copy);
        }
        let gen = solo_greedy(model, &prompt, max_new);
        let sim = simulate_single_stream(&prompt, &gen, fs.seq, chunk, draft_cap);
        if sim.2 >= 1 {
            return (prompt, gen, sim);
        }
    }
    panic!("no candidate prompt produced an accepted draft — widen the scan");
}

/// Run one generation job through the continuous batcher and return the
/// served tokens plus the scheduler's stats.
fn run_generate(
    model: ForwardModel,
    cfg: BatchConfig,
    prompt: &[i32],
    max_new: usize,
) -> (Vec<i32>, ServerStats) {
    let (srv, cli) = EvalServer::spawn_batched(model, cfg).expect("spawn batched server");
    let out = cli.generate(prompt.to_vec(), max_new).expect("generate").tokens;
    drop(cli);
    (out, srv.shutdown().expect("server shutdown"))
}

fn main() {
    let fast = benchlib::fast_mode();
    let mut results: BTreeMap<String, f64> = BTreeMap::new();
    let reps = if fast { 3 } else { 5 };
    let fs = if fast {
        ForwardSpec::new(64, 32, 2, 4, 48, 32, 1)
    } else {
        ForwardSpec::new(256, 64, 2, 4, 128, 48, 1)
    }
    .expect("bench spec");
    let block = if fast { 16 } else { 64 };
    let page_tokens = if fast { 4 } else { 8 };
    let (chunk, draft_cap) = (4usize, 4usize);
    let max_new = fs.seq / 2;

    // rtn: calibration-free AND affine-decode, so the int8 MAC arm of
    // the bit-identity grid engages for real
    let spec = synth::model_spec(&fs, "perf_spec");
    let weights = synth::synth_weights(&fs, 0x5DEC_u64);
    let qcfg = QuantConfig::block_wise(4, block).expect("cfg").with_packed();
    let opts = QuantizeOptions::new().with_threads(2);
    let qm = quantize(&spec, weights, None, Method::Rtn, &qcfg, &opts).expect("quantize");
    let payload = qm.export_packed().expect("packed payload");

    let mk_model = |mac: MacMode, kernel: Kernel, threads: usize| {
        ForwardModel::from_packed_map_with(fs.clone(), &payload, mac)
            .expect("packed model")
            .with_kernel(kernel)
            .with_threads(threads)
    };
    let base_cfg = BatchConfig {
        max_streams: 2,
        kv_page_tokens: page_tokens,
        prefill_chunk: chunk,
        linger: Duration::from_millis(5),
        ..BatchConfig::default()
    };
    let spec_cfg = BatchConfig { speculative: true, draft_len: draft_cap, ..base_cfg.clone() };

    // --- gates (a)+(b)+(c): bit-identity, step savings, page bound ---------
    let mut kernels = vec![Kernel::Scalar];
    if let Some(k) = Kernel::detect_simd() {
        kernels.push(k);
    }
    let page_slack = draft_cap.div_ceil(page_tokens);
    let mut grid = 0usize;
    for &mac in &[MacMode::F32, MacMode::Int8] {
        for &kernel in &kernels {
            for &threads in &[1usize, 4] {
                // the greedy continuation depends on the MAC path, so the
                // accepting workload is re-derived per grid point
                let m = mk_model(mac, kernel, threads);
                let (prompt, gen, (steps_sim, drafted_sim, accepted_sim)) =
                    find_accepting_workload(&m, chunk, draft_cap, max_new);
                let (plain, pstats) = run_generate(
                    mk_model(mac, kernel, threads),
                    base_cfg.clone(),
                    &prompt,
                    max_new,
                );
                let (specd, sstats) = run_generate(
                    mk_model(mac, kernel, threads),
                    spec_cfg.clone(),
                    &prompt,
                    max_new,
                );
                let tag =
                    format!("{} MAC, {} kernel, {threads} threads", mac.name(), kernel.name());
                assert_eq!(plain, gen, "plain generation diverged from solo greedy ({tag})");
                assert_eq!(specd, gen, "speculative generation diverged from solo greedy ({tag})");
                let plain_steps = (prompt.len().div_ceil(chunk) + gen.len() - 1) as u64;
                assert_eq!(pstats.batches, plain_steps, "plain step count off ({tag})");
                assert_eq!(pstats.drafted, 0, "plain run must never draft ({tag})");
                assert_eq!(sstats.batches, steps_sim, "scheduler diverged from mirror ({tag})");
                assert_eq!(sstats.drafted, drafted_sim, "drafted count off ({tag})");
                assert_eq!(sstats.accepted, accepted_sim, "accepted count off ({tag})");
                assert!(
                    sstats.batches < pstats.batches,
                    "speculative decode must take strictly fewer step_batch calls \
                     ({} vs {}, {tag})",
                    sstats.batches,
                    pstats.batches
                );
                assert!(
                    sstats.peak_pages <= pstats.peak_pages + page_slack,
                    "speculative peak {} pages exceeds plain peak {} + {page_slack} ({tag})",
                    sstats.peak_pages,
                    pstats.peak_pages
                );
                assert_eq!(sstats.leaked_pages, 0, "pages leaked after rollback ({tag})");
                grid += 1;
            }
        }
    }

    // --- throughput: plain vs speculative wall time on the same workload ---
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let kernel = Kernel::detect();
    let timed = mk_model(MacMode::F32, kernel, threads);
    let (prompt, gen, (steps_sim, drafted_sim, accepted_sim)) =
        find_accepting_workload(&timed, chunk, draft_cap, max_new);
    let new_tokens = gen.len() as f64;
    let time_arm = |cfg: &BatchConfig| -> f64 {
        let (srv, cli) =
            EvalServer::spawn_batched(mk_model(MacMode::F32, kernel, threads), cfg.clone())
                .expect("spawn batched server");
        let t = time_median(reps, || {
            let out = cli.generate(prompt.clone(), max_new).expect("generate").tokens;
            assert_eq!(out, gen, "timed arm diverged from solo greedy");
        });
        drop(cli);
        srv.shutdown().expect("server shutdown");
        t
    };
    let t_plain = time_arm(&base_cfg);
    let t_spec = time_arm(&spec_cfg);
    let plain_steps = (prompt.len().div_ceil(chunk) + gen.len() - 1) as u64;
    let accept = accepted_sim as f64 / drafted_sim.max(1) as f64;

    benchlib::header(&format!(
        "self-speculative greedy decode: vocab {} d {} L{} seq {} ({} kernel, {threads} \
         threads, chunk {chunk}, draft cap {draft_cap}, {page_tokens}-token pages)",
        fs.vocab,
        fs.d,
        fs.layers,
        fs.seq,
        kernel.name()
    ));
    println!(
        "  bit-identity: spec == plain == solo greedy on {grid} grid points \
         (mac x kernel x threads), scheduler == exact mirror on each"
    );
    println!(
        "  steps: plain {plain_steps} -> spec {steps_sim} on the timed workload \
         ({drafted_sim} drafted, {accepted_sim} accepted, {:.0}% accept rate)",
        100.0 * accept
    );
    println!(
        "  wall: plain {t_plain:.4}s ({:.1} tok/s)   spec {t_spec:.4}s ({:.1} tok/s)   {:.2}x",
        new_tokens / t_plain,
        new_tokens / t_spec,
        t_plain / t_spec
    );

    results.insert("spec-steps-base".to_string(), plain_steps as f64);
    results.insert("spec-steps-spec".to_string(), steps_sim as f64);
    results.insert("spec-accept-rate".to_string(), accept);
    results.insert("spec-speedup".to_string(), t_plain / t_spec);
    results.insert("spec-tps-base".to_string(), new_tokens / t_plain);
    results.insert("spec-tps-spec".to_string(), new_tokens / t_spec);
    results.insert("spec-grid-points".to_string(), grid as f64);
    results.insert(
        "spec-simd".to_string(),
        u64::from(Kernel::detect() != Kernel::Scalar) as f64,
    );

    match benchlib::merge_bench_json("perf", "perf_spec", &results) {
        Ok(path) => println!("\nmerged {} keys into {}", results.len(), path.display()),
        Err(e) => eprintln!("\nBENCH_perf.json not written: {e}"),
    }
}
