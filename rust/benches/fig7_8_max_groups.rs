//! Figures 7 & 8 — max-group count g vs MSE (plateaus around g≈32) and vs
//! quantization time on a 512×512 N(0,1) matrix.

use msb_quant::benchlib::{self, time_once};
use msb_quant::msb::{Algo, Solver};
use msb_quant::stats::Rng;
use msb_quant::tensor::Matrix;

fn main() {
    let n = if benchlib::fast_mode() { 128 } else { 512 };
    let mut rng = Rng::new(7);
    let w = Matrix::randn(n, n, &mut rng);

    benchlib::header(&format!("Fig 7/8 analog — max groups vs MSE & time ({n}x{n})"));
    println!("g,gg_mse,gg_time,wgm_mse,wgm_time");
    let groups: Vec<usize> = if benchlib::fast_mode() {
        vec![2, 8, 32]
    } else {
        vec![2, 4, 8, 16, 32, 64, 128, 256]
    };
    let mut last_wgm = f64::INFINITY;
    for g in groups {
        let (gg_code, gg_t) =
            time_once(|| Solver::new(Algo::Gg).quantize(&w.data, g));
        let (wgm_code, wgm_t) =
            time_once(|| Solver::new(Algo::Wgm { window: 16 }).quantize(&w.data, g));
        let (gg_mse, wgm_mse) = (gg_code.sse(&w.data), wgm_code.sse(&w.data));
        println!("{g},{gg_mse:.4},{gg_t:.3},{wgm_mse:.4},{wgm_t:.3}");
        assert!(wgm_mse <= last_wgm + 1e-9, "MSE must not increase with g");
        last_wgm = wgm_mse;
    }
    println!("\npaper shape: MSE improves then plateaus around g≈32; time roughly flat.");
}
