//! Table 5 / Table 10 — λ sensitivity: per-tensor WGM (w=256, g=256) over
//! λ̃ ∈ {0, 0.1, …, 1.0}, full PPL evaluation on the tiny model. The
//! paper's finding (reproduced here): PPL is flat in λ because GG/WGM take
//! the group count externally — λ only matters for Algorithm 1.

use msb_quant::benchlib::{self, time_once};
use msb_quant::eval;
use msb_quant::harness::Artifacts;
use msb_quant::io::msbt::Tensor;
use msb_quant::quant::{msb::MsbQuantizer, Granularity, QuantConfig, Quantizer};
use msb_quant::runtime::ModelRunner;

fn main() {
    let arts = match Artifacts::load() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("artifacts required: {e}");
            return;
        }
    };
    let spec = arts.manifest.model("tiny").expect("tiny").clone();
    let weights = arts.weights(&spec).expect("weights");
    let mut runner = ModelRunner::new(&arts.manifest, &spec, &weights).expect("runner");

    benchlib::header("Table 5 analog — λ sweep (per-tensor WGM, w=256, g=256, tiny model)");
    println!(
        "{}",
        benchlib::row(&["λ̃", "quant (s)", "wk", "pt", "c4", "avg PPL"].map(String::from))
    );

    let tildes: Vec<f64> = if benchlib::fast_mode() {
        vec![0.0, 0.5, 1.0]
    } else {
        (0..=10).map(|i| i as f64 / 10.0).collect()
    };
    let mut avgs = Vec::new();
    for tilde in tildes {
        let (qweights, dt) = time_once(|| {
            let mut out = weights.clone();
            for p in spec.quantizable() {
                let w = weights.get(&p.name).unwrap().to_matrix().unwrap();
                // QuantConfig.lambda *is* λ̃ — the quantizer applies the
                // Appendix C Λ map per instance. g=256 => 2^(9-1): the
                // oracle setting exceeds the deployable 1..=8 bit range,
                // so the config is built literally.
                let cfg = QuantConfig {
                    bits: 9,
                    granularity: Granularity::PerTensor,
                    window: 256,
                    lambda: tilde,
                    bf16: true,
                    emit_packed: false,
                };
                let q = MsbQuantizer::wgm().quantize(&w, &cfg);
                out.insert(p.name.clone(), Tensor::f32(p.shape.clone(), q.dequant.data));
            }
            out
        });
        runner.update_weights(&qweights).expect("swap");
        let mut ppls = Vec::new();
        for s in &arts.manifest.eval_streams {
            ppls.push(eval::perplexity(&runner, arts.eval_stream(s).unwrap()).unwrap());
        }
        let avg = ppls.iter().sum::<f64>() / ppls.len() as f64;
        avgs.push(avg);
        println!(
            "{}",
            benchlib::row(&[
                format!("{tilde:.1}"),
                benchlib::fmt_f(dt, 2),
                benchlib::fmt_f(ppls[2], 3), // eval_wk (sorted c4, pt, wk)
                benchlib::fmt_f(ppls[1], 3),
                benchlib::fmt_f(ppls[0], 3),
                benchlib::fmt_f(avg, 3),
            ])
        );
    }
    let spread = avgs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
        - avgs.iter().cloned().fold(f64::INFINITY, f64::min);
    println!(
        "\nPPL spread across λ̃: {spread:.4} — paper shape: negligible (λ is inert for WGM)."
    );
}
