//! Extensions ablation (paper §5 future work (iii) + §2.2 capacity
//! allocation): what the optional transform and the mixed-precision
//! allocator buy on top of plain MSB/WGM, on matrices with AWQ-style hot
//! channels and heterogeneous block energy.

use msb_quant::benchlib::{self, time_once};
use msb_quant::quant::{
    mixed::MixedMsbQuantizer,
    msb::MsbQuantizer,
    rtn::RtnQuantizer,
    transform::{weighted_sse, ScalePolicy, ScaledQuantizer},
    QuantConfig, Quantizer,
};
use msb_quant::stats::Rng;
use msb_quant::tensor::Matrix;

fn main() {
    let dim = if benchlib::fast_mode() { 256 } else { 1024 };
    let mut rng = Rng::new(0xE57);

    // weight matrix with heterogeneous block energy
    let mut w = Matrix::weightlike(dim, dim, &mut rng);
    for (bi, chunk) in w.data.chunks_mut(64).enumerate() {
        if bi % 9 == 0 {
            for v in chunk.iter_mut() {
                *v *= 6.0;
            }
        }
    }
    // activation statistics with hot channels
    let diag: Vec<f32> = (0..dim)
        .map(|_| {
            let base = rng.uniform() as f32 + 0.1;
            if rng.uniform() < 0.05 {
                base * 64.0
            } else {
                base
            }
        })
        .collect();

    let cfg = QuantConfig::block_wise(3, 64).unwrap().with_window(1).unwrap().no_bf16();
    benchlib::header(&format!("extensions ablation — {dim}x{dim}, 3-bit block-wise"));
    println!(
        "{}",
        benchlib::row(
            &["method", "SSE", "weighted SSE", "bits/w", "time (s)"].map(String::from)
        )
    );

    let report = |name: &str, qt: msb_quant::quant::QuantizedTensor, dt: f64| {
        println!(
            "{}",
            benchlib::row(&[
                name.to_string(),
                benchlib::fmt_f(qt.mse(&w), 2),
                benchlib::fmt_f(weighted_sse(&w, &qt.dequant, &diag), 1),
                benchlib::fmt_f(qt.effective_bits, 3),
                benchlib::fmt_f(dt, 3),
            ])
        );
        qt
    };

    let (qt, dt) = time_once(|| RtnQuantizer::symmetric().quantize(&w, &cfg));
    let rtn = report("rtn", qt, dt);
    let (qt, dt) = time_once(|| {
        ScaledQuantizer::new(
            RtnQuantizer::symmetric(),
            ScalePolicy::ActivationAware { diag_h: diag.clone(), alpha: 0.5 },
        )
        .quantize(&w, &cfg)
    });
    let rtn_awq = report("rtn+awq", qt, dt);
    let (qt, dt) = time_once(|| MsbQuantizer::wgm().quantize(&w, &cfg));
    let plain = report("wgm", qt, dt);
    let (qt, dt) = time_once(|| {
        ScaledQuantizer::new(
            MsbQuantizer::wgm(),
            ScalePolicy::ActivationAware { diag_h: diag.clone(), alpha: 0.5 },
        )
        .quantize(&w, &cfg)
    });
    let awq = report("wgm+awq", qt, dt);
    let (qt, dt) = time_once(|| {
        ScaledQuantizer::new(MsbQuantizer::wgm(), ScalePolicy::WeightAware { alpha: 0.3 })
            .quantize(&w, &cfg)
    });
    report("wgm+eq", qt, dt);
    let (qt, dt) = time_once(|| MixedMsbQuantizer::new(0.15).quantize(&w, &cfg));
    let mixed = report("wgm-mixed", qt, dt);
    let (qt, dt) = time_once(|| {
        MixedMsbQuantizer::new(0.15).with_diag_h(diag.clone()).quantize(&w, &cfg)
    });
    report("wgm-mixed+h", qt, dt);

    println!("\nfindings: AWQ-style rescaling helps *grid* quantizers (rtn+awq < rtn");
    println!("on weighted SSE) but not MSB — its multi-scale grouping is already");
    println!("scale-adaptive, supporting the paper's transformation-free thesis.");
    println!("Mixed precision lowers plain SSE at the same bit budget.");
    assert!(
        weighted_sse(&w, &rtn_awq.dequant, &diag) < weighted_sse(&w, &rtn.dequant, &diag),
        "awq must help the uniform grid"
    );
    let _ = &awq; // reported descriptively above
    assert!(mixed.mse(&w) < plain.mse(&w));
}
