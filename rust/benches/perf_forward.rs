//! §Perf — fused CPU transformer forward: full-sequence scoring and
//! KV-cached incremental decode, straight off the packed codes.
//!
//! The claims under test:
//!
//! * the quantized forward (`forward::ForwardModel`, every projection a
//!   `kernels::PackedLinear`) matches its f32 twin — same layer graph
//!   over the decoded weights — within 1e-4 relative on the logits;
//! * multi-threaded full-sequence scoring is bit-identical to serial
//!   (PR-5 discipline: anchored tiles, fixed reduction tree, whole rows
//!   per worker);
//! * incremental decode (one `KvState`, one token per `step`) is
//!   bit-identical to recomputing the whole prefix per position, and
//!   strictly faster — the KV cache turns O(T²) projection work into
//!   O(T).
//!
//! All three are hard asserts: no number is reported from a run that
//! fails them. Results merge into `BENCH_perf.json` (`forward-*` keys)
//! next to the engine/scheduler/gemv numbers.

use std::collections::BTreeMap;

use msb_quant::benchlib::{self, time_median};
use msb_quant::forward::{synth, ForwardModel, ForwardSpec};
use msb_quant::kernels::Kernel;
use msb_quant::pipeline::{decode_packed_model, quantize, QuantizeOptions};
use msb_quant::quant::registry::Method;
use msb_quant::quant::QuantConfig;

fn max_rel(a: &[f32], b: &[f32]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let scale = f64::from(x.abs().max(y.abs())).max(1e-3);
            (f64::from(x) - f64::from(y)).abs() / scale
        })
        .fold(0.0, f64::max)
}

/// One token column `t` of a `[batch, seq]` token slab.
fn column(toks: &[i32], batch: usize, seq: usize, t: usize) -> Vec<i32> {
    (0..batch).map(|bi| toks[bi * seq + t]).collect()
}

/// Run `seq` single-token steps through one KV cache; returns the
/// per-step `[batch, 1, vocab]` logit slabs.
fn incremental(model: &ForwardModel, toks: &[i32], fs: &ForwardSpec) -> Vec<Vec<f32>> {
    let mut kv = model.kv_state();
    (0..fs.seq)
        .map(|t| {
            model.step(&mut kv, &column(toks, fs.batch, fs.seq, t)).expect("incremental step")
        })
        .collect()
}

fn main() {
    let fast = benchlib::fast_mode();
    let mut results: BTreeMap<String, f64> = BTreeMap::new();
    let reps = if fast { 3 } else { 5 };
    let fs = if fast {
        ForwardSpec::new(64, 32, 2, 4, 48, 16, 2)
    } else {
        ForwardSpec::new(256, 64, 2, 4, 128, 32, 2)
    }
    .expect("bench spec");
    let block = if fast { 16 } else { 64 };

    let spec = synth::model_spec(&fs, "perf_forward");
    let weights = synth::synth_weights(&fs, 0xF0D_u64);
    let cfg = QuantConfig::block_wise(4, block).expect("cfg").with_packed();
    let opts = QuantizeOptions::new().with_threads(2);
    let ((payload, decoded), t_quant) = benchlib::time_once(|| {
        let qm = quantize(&spec, weights, None, Method::Wgm, &cfg, &opts).expect("quantize");
        let payload = qm.export_packed().expect("packed payload");
        let decoded = decode_packed_model(&payload, 2).expect("decode");
        (payload, decoded)
    });

    let model = ForwardModel::from_packed_map(fs.clone(), &payload).expect("packed model");
    let twin = ForwardModel::from_dense(fs.clone(), &decoded).expect("f32 twin");
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let pooled = ForwardModel::from_packed_map(fs.clone(), &payload)
        .expect("packed model")
        .with_threads(threads);

    let toks = synth::synth_tokens(&fs, fs.seq, 0x70CA_u64);
    let tokens = (fs.batch * fs.seq) as f64;

    // --- correctness gates --------------------------------------------------
    let y = model.logits(&toks).expect("serial logits");
    let rel = max_rel(&y, &twin.logits(&toks).expect("twin logits"));
    assert!(rel <= 1e-4, "quantized forward diverged from the f32 twin: {rel:.3e}");
    assert_eq!(y, pooled.logits(&toks).expect("pooled logits"), "threads != serial");

    let steps = incremental(&model, &toks, &fs);
    for (t, step) in steps.iter().enumerate() {
        let full = model.score_prefix(&toks, t + 1).expect("score_prefix");
        assert_eq!(step, &full, "incremental step {t} != full recompute of the prefix");
    }

    // --- throughput ---------------------------------------------------------
    let t_serial = time_median(reps, || model.logits(&toks).expect("serial logits"));
    let t_pooled = time_median(reps, || pooled.logits(&toks).expect("pooled logits"));
    let t_incr = time_median(reps, || incremental(&pooled, &toks, &fs));
    let t_full = time_median(reps, || {
        (0..fs.seq)
            .map(|t| pooled.score_prefix(&toks, t + 1).expect("score_prefix"))
            .collect::<Vec<_>>()
    });
    assert!(
        t_incr < t_full,
        "KV-cached incremental decode ({t_incr:.4}s) must beat per-position full \
         recompute ({t_full:.4}s)"
    );

    benchlib::header(&format!(
        "fused CPU forward: vocab {} d {} L{} seq {} batch {} ({} kernel, {threads} threads)",
        fs.vocab,
        fs.d,
        fs.layers,
        fs.seq,
        fs.batch,
        Kernel::detect().name()
    ));
    println!(
        "  payload {} B ({:.3}x of f32 projections), quantize+decode {:.2}s, max rel {rel:.2e}",
        model.payload_bytes(),
        model.payload_bytes() as f64 / model.f32_bytes() as f64,
        t_quant
    );
    println!(
        "  full-seq   serial {:>9.4}s ({:>8.1} tok/s)   pooled {:>9.4}s ({:>8.1} tok/s)",
        t_serial,
        tokens / t_serial,
        t_pooled,
        tokens / t_pooled
    );
    println!(
        "  decode     KV-cached {:>8.4}s ({:>8.1} tok/s)   recompute {:>8.4}s  ({:.2}x)",
        t_incr,
        tokens / t_incr,
        t_full,
        t_full / t_incr
    );

    let simd = u64::from(Kernel::detect() != Kernel::Scalar) as f64;
    results.insert("forward-simd".to_string(), simd);
    results.insert("forward-full-serial-tps".to_string(), tokens / t_serial);
    results.insert("forward-full-pooled-tps".to_string(), tokens / t_pooled);
    results.insert("forward-incr-tps".to_string(), tokens / t_incr);
    results.insert("forward-recompute-tps".to_string(), tokens / t_full);
    results.insert("forward-kv-speedup".to_string(), t_full / t_incr);
    results.insert("forward-max-rel".to_string(), rel);

    match benchlib::merge_bench_json("perf", "perf_forward", &results) {
        Ok(path) => println!("\nmerged {} keys into {}", results.len(), path.display()),
        Err(e) => eprintln!("\nBENCH_perf.json not written: {e}"),
    }
}
