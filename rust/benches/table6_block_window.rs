//! Table 6 / Tables 11-12 — 4-bit block-wise MSE and time over the
//! (block t × window w) grid on the proxy matrix. Paper shape: MSE falls
//! as both t and w shrink; t=64, w=1 is optimal; time grows moderately.

use msb_quant::benchlib::{self, time_once};
use msb_quant::quant::{msb::MsbQuantizer, QuantConfig, Quantizer};

fn main() {
    let dim = if benchlib::fast_mode() { 256 } else { 2048 };
    let w = benchlib::proxy_matrix(dim, dim);
    let blocks: Vec<usize> =
        [2048usize, 1024, 512, 256, 128, 64].into_iter().filter(|&t| t <= dim).collect();
    let windows: Vec<usize> = vec![64, 32, 16, 8, 4, 2, 1];

    benchlib::header(&format!("Table 6 analog — 4-bit block-wise MSE, {dim}x{dim}"));
    let mut head = vec!["w \\ t".to_string()];
    head.extend(blocks.iter().map(|t| t.to_string()));
    println!("{}", benchlib::row(&head));

    let mut times: Vec<Vec<f64>> = Vec::new();
    for &win in &windows {
        let mut cells = vec![win.to_string()];
        let mut trow = Vec::new();
        for &t in &blocks {
            if win >= t {
                cells.push("/".into());
                trow.push(f64::NAN);
                continue;
            }
            let cfg = QuantConfig::block_wise(4, t).unwrap().with_window(win).unwrap().no_bf16();
            let (qt, dt) = time_once(|| MsbQuantizer::wgm().quantize(&w, &cfg));
            cells.push(benchlib::fmt_f(qt.mse(&w), 2));
            trow.push(dt);
        }
        println!("{}", benchlib::row(&cells));
        times.push(trow);
    }

    benchlib::header("time (s) for the same grid (Table 12 analog)");
    println!("{}", benchlib::row(&head));
    for (wi, &win) in windows.iter().enumerate() {
        let mut cells = vec![win.to_string()];
        for (ti, _) in blocks.iter().enumerate() {
            let v = times[wi][ti];
            cells.push(if v.is_nan() { "/".into() } else { benchlib::fmt_f(v, 2) });
        }
        println!("{}", benchlib::row(&cells));
    }
    println!("\npaper shape: MSE decreases monotonically toward (t=64, w=1).");
}
