//! **Table 1** — the headline grid: average QA (7 suites) and average PPL
//! (3 held-out streams) for every (model × method) cell under 4-bit
//! block-wise AND 6-bit per-tensor quantization, via the full PJRT
//! evaluation path. "/" cells match the paper (BnB/GPTQ have no per-tensor
//! variant; WGM-LO is per-tensor-only).
//!
//! Paper shape to reproduce: block-wise — all methods within a few % of FP
//! with calibration-free ones competitive; per-tensor — RTN/HQQ collapse
//! while WGM/WGM-LO stay near FP.

use msb_quant::benchlib;
use msb_quant::harness::{eval_quantized, Artifacts, EvalReport};
use msb_quant::quant::registry::Method;
use msb_quant::quant::QuantConfig;
use msb_quant::runtime::ModelRunner;

fn cell(r: &EvalReport) -> (String, String) {
    (format!("{:.3}", r.avg_qa()), format!("{:.2}", r.avg_ppl()))
}

fn main() {
    let arts = match Artifacts::load() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("artifacts required: {e}");
            return;
        }
    };
    let models: Vec<_> = if benchlib::fast_mode() {
        arts.manifest.models.iter().take(1).cloned().collect()
    } else {
        arts.manifest.models.clone()
    };

    let bw_cfg = QuantConfig::block_wise(4, 64).unwrap().with_window(1).unwrap();
    let pt_cfg = QuantConfig::per_tensor(6).unwrap().with_window(64).unwrap();
    // Our trained stand-ins are far more noise-robust than billion-param
    // LLMs: the fragility the paper observes at 6-bit per-tensor appears
    // here around 3-bit, so we additionally report a 3-bit "stress" column
    // where the paper's per-tensor method ordering becomes visible.
    let pt3_cfg = QuantConfig::per_tensor(3).unwrap().with_window(64).unwrap();
    let bw_methods =
        [Method::Fp, Method::Gptq, Method::Rtn, Method::Bnb, Method::Hqq, Method::Wgm];
    let pt_methods = [Method::Rtn, Method::Hqq, Method::Wgm, Method::WgmLo];

    benchlib::header("Table 1 analog — QA↑ / PPL↓ per model and method");
    println!(
        "{}",
        benchlib::row(
            &["model", "method", "QA 4b-bw", "PPL 4b-bw", "QA 6b-pt", "PPL 6b-pt",
              "QA 3b-pt", "PPL 3b-pt"]
                .map(String::from)
        )
    );

    for spec in &models {
        let weights = arts.weights(spec).expect("weights");
        let mut runner = ModelRunner::new(&arts.manifest, spec, &weights).expect("runner");
        // collect all settings per method for the merged table
        let mut lines: Vec<(String, [String; 6])> = Vec::new();
        for method in bw_methods {
            let rep = eval_quantized(&arts, spec, &mut runner, &weights, method, &bw_cfg, 1)
                .expect("bw eval");
            let (qa, ppl) = cell(&rep);
            let rest = if method == Method::Fp {
                [qa.clone(), ppl.clone(), qa.clone(), ppl.clone()] // FP is setting-free
            } else {
                ["/".into(), "/".into(), "/".into(), "/".into()]
            };
            lines.push((
                method.name().to_string(),
                [qa, ppl, rest[0].clone(), rest[1].clone(), rest[2].clone(), rest[3].clone()],
            ));
        }
        for method in pt_methods {
            let rep6 = eval_quantized(&arts, spec, &mut runner, &weights, method, &pt_cfg, 1)
                .expect("pt6 eval");
            let rep3 = eval_quantized(&arts, spec, &mut runner, &weights, method, &pt3_cfg, 1)
                .expect("pt3 eval");
            let (qa6, ppl6) = cell(&rep6);
            let (qa3, ppl3) = cell(&rep3);
            if let Some(line) = lines.iter_mut().find(|(m, _)| *m == method.name()) {
                line.1[2] = qa6;
                line.1[3] = ppl6;
                line.1[4] = qa3;
                line.1[5] = ppl3;
            } else {
                lines.push((
                    method.name().to_string(),
                    ["/".into(), "/".into(), qa6, ppl6, qa3, ppl3],
                ));
            }
        }
        for (m, cells) in lines {
            let mut all = vec![spec.name.clone(), m];
            all.extend(cells);
            println!("{}", benchlib::row(&all));
        }
        println!();
    }
    println!("paper shape: per-tensor RTN/HQQ degrade first while WGM/WGM-LO track FP");
    println!("(visible in the 3b-pt stress column for these robust stand-ins);");
    println!("block-wise: everything close, WGM competitive without calibration.");
}
