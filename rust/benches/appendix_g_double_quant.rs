//! Appendix G (Tables 23/24) — double quantization: WGM vs WGM-dq on every
//! model, 4-bit block-wise. Shape: a small uniform QA/PPL degradation in
//! exchange for 6.00 → 4.78 bits/weight.

use msb_quant::benchlib;
use msb_quant::harness::{eval_quantized, Artifacts};
use msb_quant::quant::registry::Method;
use msb_quant::quant::QuantConfig;
use msb_quant::runtime::ModelRunner;

fn main() {
    let arts = match Artifacts::load() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("artifacts required: {e}");
            return;
        }
    };
    let cfg = QuantConfig::block_wise(4, 64).unwrap().with_window(1).unwrap();
    benchlib::header("Appendix G analog — double quantization (4-bit block-wise)");
    println!(
        "{}",
        benchlib::row(&["model", "method", "bits/w", "QA", "avg PPL"].map(String::from))
    );
    let models: Vec<_> = if benchlib::fast_mode() {
        arts.manifest.models.iter().take(1).cloned().collect()
    } else {
        arts.manifest.models.clone()
    };
    for spec in &models {
        let weights = arts.weights(spec).expect("weights");
        let mut runner = ModelRunner::new(&arts.manifest, spec, &weights).expect("runner");
        let mut deltas = Vec::new();
        for method in [Method::Wgm, Method::WgmDq] {
            let rep = eval_quantized(&arts, spec, &mut runner, &weights, method, &cfg, 1)
                .expect("eval");
            println!(
                "{}",
                benchlib::row(&[
                    spec.name.clone(),
                    rep.method.clone(),
                    benchlib::fmt_f(rep.effective_bits, 3),
                    benchlib::fmt_f(rep.avg_qa(), 3),
                    benchlib::fmt_f(rep.avg_ppl(), 3),
                ])
            );
            deltas.push((rep.avg_qa(), rep.avg_ppl()));
        }
        println!(
            "             -> ΔQA {:+.3}, ΔPPL {:+.3}",
            deltas[1].0 - deltas[0].0,
            deltas[1].1 - deltas[0].1
        );
    }
    println!("\npaper shape: dq slightly degrades QA/PPL, uniformly across models.");
}
