//! Table 4 — the DP oracle (Algorithm 1) vs WGM in the block-wise setting
//! at 3/4 bits: DP achieves strictly lower MSE at orders-of-magnitude more
//! time (the paper's "8 hrs vs 360 s" shape, scaled to our instance).

use msb_quant::benchlib::{self, time_once};
use msb_quant::quant::{msb::MsbQuantizer, QuantConfig, Quantizer};

fn main() {
    let dim = if benchlib::fast_mode() { 128 } else { 1024 };
    let w = benchlib::proxy_matrix(dim, dim);
    benchlib::header(&format!("Table 4 analog — DP oracle vs WGM, block-wise, {dim}x{dim}"));
    println!(
        "{}",
        benchlib::row(&["method", "bits", "time (s)", "MSE", "Δ vs DP"].map(String::from))
    );
    for bits in [4u32, 3] {
        // λ=0: both solvers must spend the identical per-tile bit budget
        // (DG would otherwise trade groups away against the λ penalty,
        // which is not the paper's matched-bits comparison)
        let cfg = QuantConfig::block_wise(bits, 64).unwrap().with_window(1).unwrap().no_bf16().with_lambda(0.0);
        let (dp, t_dp) = time_once(|| MsbQuantizer::dg().quantize(&w, &cfg));
        let (wgm, t_wgm) = time_once(|| MsbQuantizer::wgm().quantize(&w, &cfg));
        let (m_dp, m_wgm) = (dp.mse(&w), wgm.mse(&w));
        println!(
            "{}",
            benchlib::row(&[
                "dp".into(),
                bits.to_string(),
                benchlib::fmt_f(t_dp, 2),
                benchlib::fmt_f(m_dp, 4),
                "-".into(),
            ])
        );
        println!(
            "{}",
            benchlib::row(&[
                "wgm".into(),
                bits.to_string(),
                benchlib::fmt_f(t_wgm, 2),
                benchlib::fmt_f(m_wgm, 4),
                format!("{:+.2}", m_wgm - m_dp),
            ])
        );
        assert!(m_dp <= m_wgm + 1e-6, "oracle must win");
    }
    println!("\npaper shape: MSE(dp) < MSE(wgm); time(dp) ≫ time(wgm).");
}
