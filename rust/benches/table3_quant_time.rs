//! Table 3 — full-model quantization wall-clock: every method over all
//! three trained models (4-bit block-wise). The paper's shape: WGM is
//! 1-2 orders slower than RTN/HQQ/BnB but still tractable on CPU; GPTQ in
//! between.

use msb_quant::benchlib;
use msb_quant::harness::Artifacts;
use msb_quant::pipeline::quantize_model;
use msb_quant::quant::registry::Method;
use msb_quant::quant::QuantConfig;

fn main() {
    let arts = match Artifacts::load() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("artifacts required: {e}");
            return;
        }
    };
    let cfg = QuantConfig::block_wise(4, 64).with_window(1);
    let methods =
        [Method::Gptq, Method::Bnb, Method::Hqq, Method::Rtn, Method::Wgm];
    benchlib::header("Table 3 analog — full-model quantization time (s)");
    println!(
        "{}",
        benchlib::row(
            &["model", "params", "gptq", "bnb", "hqq", "rtn", "wgm"].map(String::from)
        )
    );
    let models: Vec<_> = if benchlib::fast_mode() {
        arts.manifest.models.iter().take(1).cloned().collect()
    } else {
        arts.manifest.models.clone()
    };
    for spec in &models {
        let weights = arts.weights(spec).expect("weights");
        let calib = arts.calib(spec).expect("calib");
        let mut cells = vec![spec.name.clone(), spec.total_params().to_string()];
        for method in methods {
            let calib_ref = method.needs_calibration().then_some(&calib);
            let qm = quantize_model(spec, weights.clone(), calib_ref, method, &cfg, 1)
                .expect("quantize");
            cells.push(benchlib::fmt_f(qm.wall_seconds, 2));
        }
        println!("{}", benchlib::row(&cells));
    }
    println!("\npaper shape: t(wgm) ≫ t(gptq) > t(bnb) ≈ t(hqq) ≈ t(rtn); scales with params.");
}
