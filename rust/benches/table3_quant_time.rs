//! Table 3 — full-model quantization wall-clock: every method over all
//! three trained models (4-bit block-wise). The paper's shape: WGM is
//! 1-2 orders slower than RTN/HQQ/BnB but still tractable on CPU; GPTQ in
//! between.
//!
//! Plus the **scheduler ablation**: the model-global `(layer, tile)` queue
//! (`pipeline::quantize`) against a reproduction of the old
//! sequential per-layer streaming on one shared pool. Runs on a synthetic
//! multi-layer model so this arm works without `artifacts/`; bit-identity
//! of the two paths is asserted before timing is reported, and the global
//! scheduler must not lose to the per-layer-barrier path. Results merge
//! into `BENCH_perf.json` (`sched-*` keys) alongside `perf_hotpath`.

use std::collections::BTreeMap;

use msb_quant::benchlib::{self, time_median};
use msb_quant::harness::Artifacts;
use msb_quant::io::manifest::{ModelSpec, ParamSpec};
use msb_quant::io::msbt::{Tensor, TensorMap};
use msb_quant::pipeline::{quantize, QuantizeOptions};
use msb_quant::pool::ThreadPool;
use msb_quant::quant::registry::{self, Method};
use msb_quant::quant::{QuantConfig, Quantizer};
use msb_quant::stats::Rng;
use msb_quant::tensor::Matrix;

/// A multi-layer stand-in model with alternating tall/wide layers (tail
/// tiles land unevenly, which is exactly where per-layer barriers hurt).
fn synthetic_model(layers: usize, dim: usize) -> (ModelSpec, TensorMap) {
    let mut rng = Rng::new(42);
    let mut params = Vec::new();
    let mut weights = TensorMap::new();
    for li in 0..layers {
        let (r, c) = if li % 2 == 0 { (dim, dim * 4) } else { (dim * 4, dim) };
        let name = format!("layer{li}.w");
        params.push(ParamSpec { name: name.clone(), shape: vec![r, c], quant: true });
        let m = Matrix::weightlike(r, c, &mut rng);
        weights.insert(name, Tensor::f32(vec![r, c], m.data));
    }
    let spec = ModelSpec {
        name: "synthetic".into(),
        d: dim,
        layers,
        heads: 4,
        ff: dim * 4,
        seq: 64,
        params,
        weights_file: String::new(),
        calib_file: String::new(),
        fwd_hlo: String::new(),
    };
    (spec, weights)
}

fn table3_grid(arts: &Artifacts) {
    let cfg = QuantConfig::block_wise(4, 64).unwrap().with_window(1).unwrap();
    let methods =
        [Method::Gptq, Method::Bnb, Method::Hqq, Method::Rtn, Method::Wgm];
    benchlib::header("Table 3 analog — full-model quantization time (s)");
    println!(
        "{}",
        benchlib::row(
            &["model", "params", "gptq", "bnb", "hqq", "rtn", "wgm"].map(String::from)
        )
    );
    let models: Vec<_> = if benchlib::fast_mode() {
        arts.manifest.models.iter().take(1).cloned().collect()
    } else {
        arts.manifest.models.clone()
    };
    for spec in &models {
        let weights = arts.weights(spec).expect("weights");
        let calib = arts.calib(spec).expect("calib");
        let mut cells = vec![spec.name.clone(), spec.total_params().to_string()];
        for method in methods {
            let calib_ref = method.needs_calibration().then_some(&calib);
            let qm = quantize(spec, weights.clone(), calib_ref, method, &cfg,
                &QuantizeOptions::new().with_threads(1))
                .expect("quantize");
            cells.push(benchlib::fmt_f(qm.wall_seconds, 2));
        }
        println!("{}", benchlib::row(&cells));
    }
    println!("\npaper shape: t(wgm) ≫ t(gptq) > t(bnb) ≈ t(hqq) ≈ t(rtn); scales with params.");
}

fn main() {
    let fast = benchlib::fast_mode();
    match Artifacts::load() {
        Ok(arts) => table3_grid(&arts),
        Err(e) => eprintln!(
            "artifacts absent ({e}); skipping the Table 3 grid — the scheduler \
             ablation below runs on synthetic weights"
        ),
    }

    // --- scheduler ablation: global queue vs sequential shared pool ------
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2).max(2);
    let (layers, dim) = if fast { (6, 128) } else { (12, 512) };
    let (spec, weights) = synthetic_model(layers, dim);
    let cfg = QuantConfig::block_wise(4, 64).unwrap().with_window(1).unwrap();
    let total_elems: usize = weights.values().map(|t| t.data.len()).sum();
    let n_blocks = (total_elems / 64) as f64;
    let reps = 3;
    benchlib::header(&format!(
        "scheduler ablation ({layers} layers, {threads} workers, wgm t=64)"
    ));

    // old path reproduction: layers stream one at a time through a shared
    // pool, each ending in its own reassembly barrier (pre-scheduler
    // pipeline, rebuilt from the public engine API). Matrices are
    // pre-extracted so the arm times pure solve + barrier cost.
    let mats: Vec<(String, Matrix)> = spec
        .quantizable()
        .map(|p| (p.name.clone(), weights.get(&p.name).unwrap().to_matrix().unwrap()))
        .collect();
    let q = registry::build_quantizer(Method::Wgm, None).unwrap();
    let t_seq = time_median(reps, || {
        let mut pool = ThreadPool::new(threads, threads * 4);
        for (_, w) in &mats {
            std::hint::black_box(q.quantize_with_pool(w, &cfg, &pool));
        }
        pool.shutdown();
    });

    // new path: every layer's tiles share one global queue; the only
    // barrier is end-of-model
    let t_global = time_median(reps, || {
        std::hint::black_box(
            quantize(&spec, weights.clone(), None, Method::Wgm, &cfg,
                &QuantizeOptions::new().with_threads(threads))
            .expect("quantize"),
        );
    });

    // bit-identity of the two paths before any number is reported
    {
        let qm = quantize(&spec, weights.clone(), None, Method::Wgm, &cfg,
            &QuantizeOptions::new().with_threads(threads))
        .expect("quantize");
        let mut pool = ThreadPool::new(threads, threads * 4);
        for (name, w) in &mats {
            let qt = q.quantize_with_pool(w, &cfg, &pool);
            assert_eq!(
                qt.dequant.data.as_slice(),
                qm.weights.get(name).unwrap().as_f32().unwrap(),
                "{name}: scheduler diverged from the sequential path"
            );
        }
        pool.shutdown();
    }

    let (bps_seq, bps_global) = (n_blocks / t_seq, n_blocks / t_global);
    println!("  sequential shared pool   {t_seq:>8.3} s   {bps_seq:>12.0} blocks/s");
    println!("  model-global scheduler   {t_global:>8.3} s   {bps_global:>12.0} blocks/s");
    println!("  speedup {:.2}x (barrier-free vs per-layer barriers)", t_seq / t_global);
    assert!(
        t_global <= t_seq * 1.10,
        "global scheduler must not lose to the sequential path: \
         {t_global:.3}s vs {t_seq:.3}s"
    );

    let mut results: BTreeMap<String, f64> = BTreeMap::new();
    results.insert("sched-sequential-bps".to_string(), bps_seq);
    results.insert("sched-global-bps".to_string(), bps_global);
    results.insert("sched-speedup".to_string(), t_seq / t_global);
    match benchlib::merge_bench_json("perf", "table3_quant_time", &results) {
        Ok(path) => println!("\nmerged {} ({} sched keys)", path.display(), results.len()),
        Err(e) => eprintln!("\nBENCH_perf.json not merged: {e}"),
    }
}
