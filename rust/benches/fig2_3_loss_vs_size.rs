//! Figures 2 & 3 — quantization loss vs matrix size n×n on N(0,1)
//! instances: DG (small sizes only), GG, WGM against XNOR, BLOCKED-XNOR and
//! the all-zero dummy. Emits CSV-ish series for plotting.

use msb_quant::benchlib;
use msb_quant::quant::{
    msb::MsbQuantizer, xnor::{XnorQuantizer, ZeroQuantizer}, QuantConfig, Quantizer,
};
use msb_quant::stats::Rng;
use msb_quant::tensor::Matrix;

fn mse_of(q: &dyn Quantizer, w: &Matrix, cfg: &QuantConfig) -> f64 {
    q.quantize(w, cfg).mse(w)
}

fn main() {
    let cfg = QuantConfig::per_tensor(4).unwrap().no_bf16().with_lambda(0.0);
    let bcfg = QuantConfig::block_wise(4, 64).unwrap().no_bf16().with_lambda(0.0);

    benchlib::header("Fig 2 analog — small matrices (per-tensor g=8, λ=0)");
    println!("n,dg,gg,wgm_w16,xnor,blocked_xnor,zero");
    let small: Vec<usize> =
        if benchlib::fast_mode() { vec![4, 16, 64] } else { vec![2, 4, 8, 16, 32, 64, 96, 128] };
    for n in small {
        let mut rng = Rng::new(1000 + n as u64);
        let w = Matrix::randn(n, n, &mut rng);
        let dg = mse_of(&MsbQuantizer::dg(), &w, &cfg);
        let gg = mse_of(&MsbQuantizer::gg(), &w, &cfg);
        let wgm =
            mse_of(&MsbQuantizer::wgm(), &w, &cfg.clone().with_window(16).unwrap());
        let xn = mse_of(&XnorQuantizer::whole(), &w, &cfg);
        let bx = mse_of(&XnorQuantizer::blocked(), &w, &bcfg);
        let zero = mse_of(&ZeroQuantizer, &w, &cfg);
        println!("{n},{dg:.5},{gg:.5},{wgm:.5},{xn:.5},{bx:.5},{zero:.5}");
        // figure's claim: our methods sit at/below XNOR, far below zero.
        // (dg may trade SSE for fewer groups at tiny n: its λ̃ honors the
        // Λ(λ̃) ≥ λ_min penalty by construction, unlike fixed-g heuristics.)
        assert!(dg <= xn + 1e-9 && gg <= zero && wgm <= xn + 1e-9);
    }

    benchlib::header("Fig 3 analog — large matrices (DG omitted: infeasible, as in the paper)");
    println!("n,gg,wgm_w16,wgm_w64,xnor,blocked_xnor,zero");
    let large: Vec<usize> =
        if benchlib::fast_mode() { vec![256] } else { vec![256, 512, 1024, 2048] };
    for n in large {
        let mut rng = Rng::new(2000 + n as u64);
        let w = Matrix::randn(n, n, &mut rng);
        let gg = mse_of(&MsbQuantizer::gg(), &w, &cfg);
        let w16 = mse_of(&MsbQuantizer::wgm(), &w, &cfg.clone().with_window(16).unwrap());
        let w64 = mse_of(&MsbQuantizer::wgm(), &w, &cfg.clone().with_window(64).unwrap());
        let xn = mse_of(&XnorQuantizer::whole(), &w, &cfg);
        let bx = mse_of(&XnorQuantizer::blocked(), &w, &bcfg);
        let zero = mse_of(&ZeroQuantizer, &w, &cfg);
        println!("{n},{gg:.4},{w16:.4},{w64:.4},{xn:.4},{bx:.4},{zero:.4}");
    }
    println!("\npaper shape: zero ≫ xnor ≈ blocked-xnor ≫ our methods (near the oracle).");
}
