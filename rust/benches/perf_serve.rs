//! §Perf — continuous-batching decode over the paged KV arena
//! (`forward::KvArena` + `ForwardModel::step_batch`).
//!
//! The claims under test:
//!
//! * batched decode is *bit-identical* to solo scoring: feeding N
//!   streams through staggered `step_batch` chunks reproduces each
//!   stream's solo `step` logits exactly, across MAC modes (f32, int8),
//!   dot kernels (scalar, detected SIMD), and thread counts (1, 4) —
//!   per-column independence of the fused GEMM plus activation-anchored
//!   chunking make coalescing a pure layout change;
//! * batched decode throughput strictly beats solo sequential decode at
//!   ≥2 streams — one N-row GEMM per projection per step instead of N
//!   separate GEMV passes;
//! * the page arena's peak footprint never exceeds the sum of naive
//!   per-request caches, pages are recycled the moment a stream
//!   retires, and a second wave of streams re-uses them (the peak
//!   high-water mark does not move).
//!
//! All three are hard asserts: no number is reported from a run that
//! fails them. Results merge into `BENCH_perf.json` (`serve-*` keys)
//! next to the engine/scheduler/gemv/forward numbers.

use std::collections::BTreeMap;

use msb_quant::benchlib::{self, time_median};
use msb_quant::forward::{synth, ForwardModel, ForwardSpec, KvArena, StreamSlot};
use msb_quant::kernels::{Kernel, MacMode};
use msb_quant::pipeline::{quantize, QuantizeOptions};
use msb_quant::quant::registry::Method;
use msb_quant::quant::QuantConfig;

/// One full-chunk solo pass: the ground truth `step_batch` must match.
fn solo_logits(model: &ForwardModel, toks: &[i32]) -> Vec<f32> {
    let mut kv = model.kv_state();
    model.step(&mut kv, toks).expect("solo step")
}

/// Drive every prompt through a *staggered* `step_batch` schedule on the
/// given arena — stream i advances `1 + (i + round) % 3` tokens per
/// round, so chunk boundaries differ per stream and streams retire at
/// different steps. Each stream's pages are freed the moment its last
/// token is fed (the scheduler's recycling discipline). Returns each
/// stream's concatenated logit rows.
fn run_wave(model: &ForwardModel, arena: &mut KvArena, prompts: &[Vec<i32>]) -> Vec<Vec<f32>> {
    let vocab = model.spec().vocab;
    let ids: Vec<_> = prompts.iter().map(|_| arena.alloc_stream()).collect();
    let mut fed = vec![0usize; prompts.len()];
    let mut out: Vec<Vec<f32>> = vec![Vec::new(); prompts.len()];
    for round in 0.. {
        let mut widths = Vec::new();
        let mut slots = Vec::new();
        for (i, p) in prompts.iter().enumerate() {
            let left = p.len() - fed[i];
            if left == 0 {
                continue;
            }
            let w = left.min(1 + (i + round) % 3);
            slots.push(StreamSlot { id: ids[i], tokens: &p[fed[i]..fed[i] + w] });
            widths.push((i, w));
        }
        if slots.is_empty() {
            break;
        }
        let res = model.step_batch(arena, &slots).expect("step_batch");
        for ((i, w), rows) in widths.into_iter().zip(res) {
            assert_eq!(rows.len(), w * vocab, "stream {i}: wrong logit row count");
            out[i].extend(rows);
            fed[i] += w;
            if fed[i] == prompts[i].len() {
                arena.free_stream(ids[i]);
            }
        }
    }
    out
}

/// Sequential solo decode: each stream token-by-token through its own
/// KV state, one stream after another — the no-batching baseline.
fn solo_decode(model: &ForwardModel, prompts: &[Vec<i32>]) {
    for p in prompts {
        let mut kv = model.kv_state();
        for t in 0..p.len() {
            model.step(&mut kv, &p[t..t + 1]).expect("solo decode step");
        }
    }
}

/// Coalesced decode: all streams advance one token per `step_batch`.
fn batched_decode(model: &ForwardModel, arena: &mut KvArena, prompts: &[Vec<i32>]) {
    let ids: Vec<_> = prompts.iter().map(|_| arena.alloc_stream()).collect();
    let steps = prompts.iter().map(|p| p.len()).max().unwrap_or(0);
    for t in 0..steps {
        let slots: Vec<StreamSlot> = prompts
            .iter()
            .enumerate()
            .filter(|(_, p)| t < p.len())
            .map(|(i, p)| StreamSlot { id: ids[i], tokens: &p[t..t + 1] })
            .collect();
        model.step_batch(arena, &slots).expect("batched decode step");
    }
    for id in ids {
        arena.free_stream(id);
    }
}

fn main() {
    let fast = benchlib::fast_mode();
    let mut results: BTreeMap<String, f64> = BTreeMap::new();
    let reps = if fast { 3 } else { 5 };
    let fs = if fast {
        ForwardSpec::new(64, 32, 2, 4, 48, 16, 1)
    } else {
        ForwardSpec::new(256, 64, 2, 4, 128, 32, 1)
    }
    .expect("bench spec");
    let block = if fast { 16 } else { 64 };
    let page_tokens = if fast { 4 } else { 8 };
    let seq = fs.seq;

    // rtn: calibration-free AND affine-decode, so the int8 MAC arm of
    // the bit-identity grid engages for real
    let spec = synth::model_spec(&fs, "perf_serve");
    let weights = synth::synth_weights(&fs, 0x5E21_u64);
    let cfg = QuantConfig::block_wise(4, block).expect("cfg").with_packed();
    let opts = QuantizeOptions::new().with_threads(2);
    let qm = quantize(&spec, weights, None, Method::Rtn, &cfg, &opts).expect("quantize");
    let payload = qm.export_packed().expect("packed payload");

    // --- gate (a): batched bit-identical to solo across the grid -----------
    let lens = [seq, seq / 2 + 1, seq - 3, 5];
    let prompts: Vec<Vec<i32>> = lens
        .iter()
        .enumerate()
        .map(|(i, &l)| synth::synth_tokens(&fs, l.max(1), 0xBEE5 + i as u64))
        .collect();
    let mut kernels = vec![Kernel::Scalar];
    if let Some(k) = Kernel::detect_simd() {
        kernels.push(k);
    }
    let mut grid = 0usize;
    for &mac in &[MacMode::F32, MacMode::Int8] {
        for &kernel in &kernels {
            for &threads in &[1usize, 4] {
                let m = ForwardModel::from_packed_map_with(fs.clone(), &payload, mac)
                    .expect("packed model")
                    .with_kernel(kernel)
                    .with_threads(threads);
                let solo: Vec<Vec<f32>> = prompts.iter().map(|p| solo_logits(&m, p)).collect();
                let mut arena = m.kv_arena(prompts.len(), page_tokens).expect("arena");
                let batched = run_wave(&m, &mut arena, &prompts);
                for (i, (got, want)) in batched.iter().zip(&solo).enumerate() {
                    assert_eq!(
                        got,
                        want,
                        "stream {i} diverged from solo ({} MAC, {} kernel, {threads} threads)",
                        mac.name(),
                        kernel.name()
                    );
                }
                grid += 1;
            }
        }
    }

    // --- gate (c): arena footprint + page recycling -------------------------
    let model = ForwardModel::from_packed_map_with(fs.clone(), &payload, MacMode::F32)
        .expect("packed model");
    let mut arena = model.kv_arena(prompts.len(), page_tokens).expect("arena");
    let wave1 = run_wave(&model, &mut arena, &prompts);
    assert_eq!(arena.pages_in_use(), 0, "pages must all return to the free list");
    assert!(arena.live_streams() == 0, "all streams must retire");
    let peak1 = arena.peak_pages();
    assert!(peak1 > 0, "wave must have touched pages");
    let wave2 = run_wave(&model, &mut arena, &prompts);
    assert_eq!(wave1, wave2, "recycled pages changed the math");
    assert_eq!(
        arena.peak_pages(),
        peak1,
        "second wave grew the high-water mark: pages were not recycled"
    );
    let naive_bytes = prompts.len() * arena.naive_stream_bytes();
    assert!(
        arena.peak_bytes() <= naive_bytes,
        "arena peak {} B exceeds {} B of naive per-request caches",
        arena.peak_bytes(),
        naive_bytes
    );

    // --- gate (b) + throughput: solo sequential vs coalesced decode --------
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let pooled = ForwardModel::from_packed_map_with(fs.clone(), &payload, MacMode::F32)
        .expect("packed model")
        .with_threads(threads);
    let stream_counts: &[usize] = if fast { &[1, 2, 4] } else { &[1, 2, 4, 8] };
    let mut rows = Vec::new();
    for &n in stream_counts {
        let ps: Vec<Vec<i32>> =
            (0..n).map(|i| synth::synth_tokens(&fs, seq, 0xDECD + i as u64)).collect();
        let tokens = (n * seq) as f64;
        let t_solo = time_median(reps, || solo_decode(&pooled, &ps));
        let t_batch = time_median(reps, || {
            let mut a = pooled.kv_arena(n, page_tokens).expect("arena");
            batched_decode(&pooled, &mut a, &ps);
        });
        let (solo_tps, batch_tps) = (tokens / t_solo, tokens / t_batch);
        if n >= 2 {
            assert!(
                batch_tps > solo_tps,
                "{n} streams: batched decode ({batch_tps:.1} tok/s) must strictly beat \
                 solo sequential ({solo_tps:.1} tok/s)"
            );
        }
        if n == 1 {
            results.insert("serve-solo-tps".to_string(), solo_tps);
        }
        results.insert(format!("serve-batched-s{n}-tps"), batch_tps);
        results.insert(format!("serve-speedup-s{n}"), t_solo / t_batch);
        rows.push((n, t_solo, t_batch, solo_tps, batch_tps));
    }

    benchlib::header(&format!(
        "continuous-batching decode: vocab {} d {} L{} seq {seq} ({} kernel, {threads} \
         threads, {page_tokens}-token pages)",
        fs.vocab,
        fs.d,
        fs.layers,
        Kernel::detect().name()
    ));
    println!(
        "  bit-identity: batched == solo on {grid} grid points \
         (mac x kernel x threads), {} streams each",
        prompts.len()
    );
    println!(
        "  arena: peak {} of {} pages = {} B vs {} B naive ({:.2}x), recycled across waves",
        peak1,
        arena.total_pages(),
        arena.peak_bytes(),
        naive_bytes,
        naive_bytes as f64 / arena.peak_bytes().max(1) as f64
    );
    for (n, t_solo, t_batch, solo_tps, batch_tps) in rows {
        println!(
            "  {n} stream(s): solo {t_solo:>8.4}s ({solo_tps:>8.1} tok/s)   batched \
             {t_batch:>8.4}s ({batch_tps:>8.1} tok/s)   {:.2}x",
            t_solo / t_batch
        );
    }

    let simd = u64::from(Kernel::detect() != Kernel::Scalar) as f64;
    results.insert("serve-simd".to_string(), simd);
    results.insert("serve-arena-peak-bytes".to_string(), arena.peak_bytes() as f64);
    results.insert("serve-naive-bytes".to_string(), naive_bytes as f64);
    results.insert("serve-grid-points".to_string(), grid as f64);

    match benchlib::merge_bench_json("perf", "perf_serve", &results) {
        Ok(path) => println!("\nmerged {} keys into {}", results.len(), path.display()),
        Err(e) => eprintln!("\nBENCH_perf.json not written: {e}"),
    }
}
