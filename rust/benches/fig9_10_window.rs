//! Figures 9 & 10 — window size w vs loss and vs speed on a 512×512 N(0,1)
//! matrix: MSE near-minimal below w≈64, speed gains flatten past w≈64-1024
//! — the basis for the paper's w=64 default.

use msb_quant::benchlib::{self, time_once};
use msb_quant::msb::{Algo, Solver};
use msb_quant::stats::Rng;
use msb_quant::tensor::Matrix;

fn main() {
    let n = if benchlib::fast_mode() { 128 } else { 512 };
    let mut rng = Rng::new(8);
    let w = Matrix::randn(n, n, &mut rng);

    // g=256 as in the paper's D.6 sweep (w is swept at high group budget)
    benchlib::header(&format!("Fig 9/10 analog — window size vs MSE & time ({n}x{n}, g=256)"));
    println!("w,mse,time");
    let windows: Vec<usize> = if benchlib::fast_mode() {
        vec![1, 16, 256]
    } else {
        vec![1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024]
    };
    let mut rows = Vec::new();
    for win in windows {
        let (code, t) =
            time_once(|| Solver::new(Algo::Wgm { window: win }).quantize(&w.data, 256));
        let mse = code.sse(&w.data);
        println!("{win},{mse:.4},{t:.4}");
        rows.push((win, mse, t));
    }
    // shape check: small windows near-best MSE
    let best = rows.iter().map(|r| r.1).fold(f64::INFINITY, f64::min);
    assert!((rows[0].1 - best).abs() < best * 0.15 + 1e-9, "w=1 should be ~best");
    println!("\npaper shape: MSE flat below w≈64 then rises; time falls as w grows.");
}
