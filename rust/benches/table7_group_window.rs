//! Table 7 (a/b) / Tables 8-9 — per-tensor PPL sweeps on the tiny model:
//! (a) max-group g = 2^{bit-1} for bit ∈ 4..10 at w=256 — PPL collapses at
//!     low bit counts and saturates around bit 6-8;
//! (b) window w ∈ {8..512} at g=256 — PPL degrades once w exceeds ~64.

use msb_quant::benchlib::{self, time_once};
use msb_quant::eval;
use msb_quant::harness::Artifacts;
use msb_quant::io::msbt::Tensor;
use msb_quant::quant::{msb::MsbQuantizer, Granularity, QuantConfig, Quantizer};
use msb_quant::runtime::ModelRunner;

/// Oracle sweeps run past the deployable 1..=8 bit range (g up to 512), so
/// the config is built literally instead of via the validated constructors.
fn per_tensor_oracle(bits: u32, window: usize) -> QuantConfig {
    QuantConfig {
        bits,
        granularity: Granularity::PerTensor,
        window,
        lambda: 0.75,
        bf16: true,
        emit_packed: false,
    }
}

fn eval_cfg(
    arts: &Artifacts,
    runner: &mut ModelRunner,
    weights: &msb_quant::io::msbt::TensorMap,
    spec: &msb_quant::io::manifest::ModelSpec,
    cfg: &QuantConfig,
) -> (f64, f64) {
    let (qweights, dt) = time_once(|| {
        let mut out = weights.clone();
        for p in spec.quantizable() {
            let w = weights.get(&p.name).unwrap().to_matrix().unwrap();
            let q = MsbQuantizer::wgm().quantize(&w, cfg);
            out.insert(p.name.clone(), Tensor::f32(p.shape.clone(), q.dequant.data));
        }
        out
    });
    runner.update_weights(&qweights).expect("swap");
    let mut total = 0.0;
    for s in &arts.manifest.eval_streams {
        total += eval::perplexity(runner, arts.eval_stream(s).unwrap()).unwrap();
    }
    (total / arts.manifest.eval_streams.len() as f64, dt)
}

fn main() {
    let arts = match Artifacts::load() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("artifacts required: {e}");
            return;
        }
    };
    let spec = arts.manifest.model("tiny").expect("tiny").clone();
    let weights = arts.weights(&spec).expect("weights");
    let mut runner = ModelRunner::new(&arts.manifest, &spec, &weights).expect("runner");

    benchlib::header("Table 7a analog — max-group sweep (per-tensor, w=256, tiny)");
    println!("{}", benchlib::row(&["bit", "g", "quant (s)", "avg PPL"].map(String::from)));
    let bits: Vec<u32> =
        if benchlib::fast_mode() { vec![4, 6, 8] } else { vec![4, 5, 6, 7, 8, 9, 10] };
    for bit in bits {
        let cfg = per_tensor_oracle(bit, 256);
        let (ppl, dt) = eval_cfg(&arts, &mut runner, &weights, &spec, &cfg);
        println!(
            "{}",
            benchlib::row(&[
                bit.to_string(),
                (1usize << (bit - 1)).to_string(),
                benchlib::fmt_f(dt, 2),
                benchlib::fmt_f(ppl, 3),
            ])
        );
    }

    benchlib::header("Table 7b analog — window sweep (per-tensor, g=256, tiny)");
    println!("{}", benchlib::row(&["w", "quant (s)", "avg PPL"].map(String::from)));
    let windows: Vec<usize> =
        if benchlib::fast_mode() { vec![8, 64, 512] } else { vec![8, 16, 32, 64, 128, 256, 512] };
    for w in windows {
        let cfg = per_tensor_oracle(9, w);
        let (ppl, dt) = eval_cfg(&arts, &mut runner, &weights, &spec, &cfg);
        println!(
            "{}",
            benchlib::row(&[w.to_string(), benchlib::fmt_f(dt, 2), benchlib::fmt_f(ppl, 3)])
        );
    }
    println!("\npaper shape: (a) PPL explodes at bit≤4-5, saturates by ~bit 7;");
    println!("             (b) flat until w≈64, degrades beyond.");
}
