//! §Perf — fused packed-weight GEMV vs decode-then-matmul ablation.
//!
//! The claim under test: computing `y = W·x` directly on the codes
//! (`kernels::PackedLinear`) beats decoding the packed payload to a full
//! f32 matrix and multiplying — because the fused path touches only the
//! 4–6x-smaller payload and never allocates, writes, or re-reads the f32
//! weight buffer. Self-asserting before any number is reported:
//!
//! * fused output matches the f64 decode-then-matvec reference to 1e-5
//!   relative (per row, scaled by the row's |w·x| mass);
//! * serial, pooled, scalar and SIMD fused paths are bit-identical;
//! * fused throughput >= the decode-then-matmul baseline;
//! * the fused call's **peak heap allocation** stays under `n` bytes —
//!   a quarter of the `4n`-byte f32 weight buffer the baseline must
//!   materialize (tracked by a counting global allocator; the baseline is
//!   also measured and must exceed `4n`, proving the counter sees it);
//! * both MAC paths issue a **bounded number of heap allocations** per
//!   call (the per-tile scratch is a stack `TileScratch`, hoisted out of
//!   the row loop — the count must not scale with rows);
//! * the **int8 MAC arm** (rtn-u4, always 512-dim): int8 gemv beats the
//!   f32 fused path at 1 and 4 threads, scalar/SIMD/pooled int8 are
//!   bit-identical, and a 1-layer synthetic forward under `mac=int8`
//!   lands within 1e-2 L2-relative of its f32-MAC twin (ppl drift
//!   reported via `eval::perplexity`).
//!
//! Results merge into `BENCH_perf.json` (`gemv-*` / `int8-*` keys) next
//! to the engine/scheduler numbers via `benchlib::merge_bench_json`.

use std::alloc::{GlobalAlloc, Layout, System};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use msb_quant::benchlib::{self, time_median};
use msb_quant::kernels::{assert_matvec_close, dense_gemv, Kernel, PackedLinear};
use msb_quant::pool::ThreadPool;
use msb_quant::quant::engine::{decode_packed, quantize_serial, BlockQuantizer};
use msb_quant::quant::msb::MsbQuantizer;
use msb_quant::quant::rtn::RtnQuantizer;
use msb_quant::quant::xnor::XnorQuantizer;
use msb_quant::quant::QuantConfig;
use msb_quant::stats::Rng;

/// Counting allocator: tracks live bytes and their high-water mark so the
/// bench can assert the fused path never materializes an f32-sized
/// buffer. Wraps `System`; the accounting is two relaxed atomics per
/// alloc/dealloc, identical overhead for both sides of the ablation.
struct CountingAlloc;

static LIVE: AtomicUsize = AtomicUsize::new(0);
static PEAK: AtomicUsize = AtomicUsize::new(0);
static COUNT: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            let live = LIVE.fetch_add(layout.size(), Ordering::Relaxed) + layout.size();
            PEAK.fetch_max(live, Ordering::Relaxed);
            COUNT.fetch_add(1, Ordering::Relaxed);
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        LIVE.fetch_sub(layout.size(), Ordering::Relaxed);
        System.dealloc(ptr, layout);
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            let live = LIVE.fetch_add(new_size, Ordering::Relaxed) + new_size;
            PEAK.fetch_max(live, Ordering::Relaxed);
            COUNT.fetch_add(1, Ordering::Relaxed);
            LIVE.fetch_sub(layout.size(), Ordering::Relaxed);
        }
        p
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Run `f` and return its peak heap growth in bytes over the live
/// baseline at entry. Only meaningful for single-threaded `f` (the
/// measured calls below are serial).
fn peak_alloc_of<R>(f: impl FnOnce() -> R) -> (R, usize) {
    let base = LIVE.load(Ordering::Relaxed);
    PEAK.store(base, Ordering::Relaxed);
    let r = f();
    let peak = PEAK.load(Ordering::Relaxed);
    (r, peak.saturating_sub(base))
}

/// Run `f` and return how many heap allocations it issued. Same
/// single-threaded caveat as [`peak_alloc_of`].
fn alloc_count_of<R>(f: impl FnOnce() -> R) -> (R, usize) {
    let base = COUNT.load(Ordering::Relaxed);
    let r = f();
    (r, COUNT.load(Ordering::Relaxed) - base)
}

fn activation(cols: usize, seed: u64) -> Vec<f32> {
    let mut x = vec![0.0f32; cols];
    Rng::new(seed).fill_normal(&mut x, 1.0);
    x
}

struct Case {
    label: &'static str,
    q: Arc<dyn BlockQuantizer>,
    cfg: QuantConfig,
    rows: usize,
    cols: usize,
}

fn main() {
    let fast = benchlib::fast_mode();
    let mut results: BTreeMap<String, f64> = BTreeMap::new();
    let dim = if fast { 256 } else { 2048 };
    let reps = if fast { 3 } else { 5 };

    let cases = vec![
        Case {
            label: "msb-wgm-u4",
            q: Arc::new(MsbQuantizer::wgm()),
            cfg: QuantConfig::block_wise(4, 64).unwrap().with_window(1).unwrap(),
            rows: dim,
            cols: dim,
        },
        Case {
            label: "rtn-u4",
            q: Arc::new(RtnQuantizer::symmetric()),
            cfg: QuantConfig::block_wise(4, 64).unwrap(),
            rows: dim,
            cols: dim,
        },
        Case {
            label: "xnor-u1",
            q: Arc::new(XnorQuantizer::blocked()),
            cfg: QuantConfig::block_wise(1, 64).unwrap(),
            rows: dim,
            cols: dim,
        },
        Case {
            label: "msb-wgm-u2",
            q: Arc::new(MsbQuantizer::wgm()),
            cfg: QuantConfig::block_wise(2, 64).unwrap().with_window(1).unwrap(),
            rows: dim,
            cols: dim,
        },
        Case {
            label: "msb-wgm-i8",
            q: Arc::new(MsbQuantizer::wgm()),
            cfg: QuantConfig::per_tensor(6).unwrap().with_window(16).unwrap(),
            rows: dim.min(512),
            cols: dim.min(512),
        },
    ];

    let kernel = Kernel::detect();
    benchlib::header(&format!("fused GEMV vs decode+matmul ({} kernel)", kernel.name()));
    results.insert("gemv-simd".to_string(), u64::from(kernel != Kernel::Scalar) as f64);

    for case in &cases {
        let mut w = benchlib::proxy_matrix(case.rows, case.cols);
        for i in (0..w.len()).step_by(397) {
            w.data[i] = 0.0; // keep the zero-exception path on the hot loop
        }
        let cfg = case.cfg.clone().with_packed();
        let qt = quantize_serial(&*case.q, &w, &cfg);
        let pt = qt.packed.expect("packed payload");
        let n = pt.n_elems();
        let n_blocks = pt.n_blocks() as f64;
        let decoded = decode_packed(Arc::clone(&case.q), &pt, None);
        assert_eq!(decoded.data, qt.dequant.data, "{}: decode sanity", case.label);

        let pl = PackedLinear::new(pt).expect("fused handle");
        let x = activation(case.cols, 0xBEA7);

        // --- correctness gates -----------------------------------------
        let (y, fused_peak) = peak_alloc_of(|| pl.gemv(&x));
        assert_matvec_close(&decoded, &x, &y, 1e-5);
        let scalar = pl.clone().with_kernel(Kernel::Scalar);
        assert_eq!(scalar.gemv(&x), y, "{}: SIMD != scalar", case.label);

        // --- the headline assertion: no f32 weight buffer ---------------
        let (_, base_peak) = peak_alloc_of(|| {
            let m = decode_packed(Arc::clone(&case.q), pl.packed(), None);
            dense_gemv(&m, &x, kernel)
        });
        assert!(
            fused_peak < n,
            "{}: fused gemv peaked at {fused_peak} B — must stay under {n} B \
             (no f32 weight buffer; f32 would be {} B)",
            case.label,
            4 * n
        );
        assert!(
            base_peak >= 4 * n,
            "{}: baseline peak {base_peak} B should include the {} B f32 buffer \
             (allocation counter broken?)",
            case.label,
            4 * n
        );

        // --- throughput --------------------------------------------------
        let t_fused = time_median(reps, || pl.gemv(&x));
        let t_base = time_median(reps, || {
            let m = decode_packed(Arc::clone(&case.q), pl.packed(), None);
            dense_gemv(&m, &x, kernel)
        });
        assert!(
            t_fused <= t_base,
            "{}: fused {t_fused:.5}s slower than decode+matmul {t_base:.5}s",
            case.label
        );
        println!(
            "  {:<12} fused {:>9.5}s ({:>11.0} blk/s, peak {:>7} B)   \
             decode+mm {:>9.5}s ({:.2}x)",
            case.label,
            t_fused,
            n_blocks / t_fused,
            fused_peak,
            t_base,
            t_base / t_fused
        );
        results.insert(format!("gemv-fused-{}-bps", case.label), n_blocks / t_fused);
        results.insert(format!("gemv-decode-{}-bps", case.label), n_blocks / t_base);
        results.insert(format!("gemv-speedup-{}", case.label), t_base / t_fused);
    }

    // --- pooled + batched arms on the paper-point case ---------------------
    let case = &cases[0];
    let cfg = case.cfg.clone().with_packed();
    let w = benchlib::proxy_matrix(case.rows, case.cols);
    let pt = quantize_serial(&*case.q, &w, &cfg).packed.expect("packed payload");
    let n_blocks = pt.n_blocks() as f64;
    let pl = PackedLinear::new(pt).expect("fused handle");
    let x = activation(case.cols, 0xBEA8);
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut pool = ThreadPool::new(threads, threads * 4);
    let y = pl.gemv(&x);
    assert_eq!(y, pl.gemv_pooled(&x, &pool), "pooled gemv != serial");
    let t_pooled = time_median(reps, || pl.gemv_pooled(&x, &pool));
    let batch = 8usize;
    let mut xs = vec![0.0f32; batch * case.cols];
    Rng::new(0xBEA9).fill_normal(&mut xs, 1.0);
    let t_gemm = time_median(reps, || pl.gemm_pooled(&xs, batch, &pool));
    pool.shutdown();
    benchlib::header(&format!("pooled fused GEMV ({threads} workers)"));
    println!(
        "  msb-wgm-u4   pooled {:>9.5}s ({:>11.0} blk/s)   gemm x{batch} {:>9.5}s \
         ({:>11.0} blk/s amortized)",
        t_pooled,
        n_blocks / t_pooled,
        t_gemm,
        n_blocks * batch as f64 / t_gemm
    );
    results.insert("gemv-pooled-bps".to_string(), n_blocks / t_pooled);
    results.insert("gemv-gemm8-bps".to_string(), n_blocks * batch as f64 / t_gemm);

    // --- integer MAC arm: rtn-u4, fixed 512-dim so the comparison is
    // meaningful even under MSB_BENCH_FAST ------------------------------
    {
        use msb_quant::eval::perplexity;
        use msb_quant::forward::{synth, ForwardSpec};
        use msb_quant::kernels::MacMode;
        use msb_quant::pipeline::{quantize, QuantizeOptions};
        use msb_quant::quant::registry::Method;
        use msb_quant::runtime::BackendBuilder;

        let dim8 = 512usize;
        let reps8 = reps.max(5);
        let q8: Arc<dyn BlockQuantizer> = Arc::new(RtnQuantizer::symmetric());
        let cfg8 = QuantConfig::block_wise(4, 64).unwrap().with_packed();
        let mut w = benchlib::proxy_matrix(dim8, dim8);
        for i in (0..w.len()).step_by(397) {
            w.data[i] = 0.0; // exceptions must ride the int8 epilogue too
        }
        let pt = quantize_serial(&*q8, &w, &cfg8).packed.expect("packed payload");
        let n_blocks8 = pt.n_blocks() as f64;
        let pl = PackedLinear::new(pt).expect("fused handle");
        assert!(pl.int8_eligible(), "rtn-u4 must be int8-eligible");
        let pl8 = pl.clone().with_mac(MacMode::Int8).expect("int8 handle");
        let x = activation(dim8, 0xBEAA);
        let decoded = decode_packed(Arc::clone(&q8), pl.packed(), None);

        // correctness + determinism gates
        let y8 = pl8.gemv(&x);
        assert_matvec_close(&decoded, &x, &y8, 2.5e-2);
        let scalar8 = pl8.clone().with_kernel(Kernel::Scalar);
        assert_eq!(scalar8.gemv(&x), y8, "int8 SIMD != scalar");

        // scratch-hoist gate: allocations per call are a small constant
        // (output + activation codes/scales), never a per-row scratch
        let (_, f32_allocs) = alloc_count_of(|| pl.gemv(&x));
        let (_, int8_allocs) = alloc_count_of(|| pl8.gemv(&x));
        assert!(
            f32_allocs <= 8,
            "f32 gemv issued {f32_allocs} allocations (scratch not hoisted?)"
        );
        assert!(
            int8_allocs <= 8,
            "int8 gemv issued {int8_allocs} allocations (scratch not hoisted?)"
        );

        // int8 beats the f32 fused path at equal threads: serial (1) ...
        let tf1 = time_median(reps8, || pl.gemv(&x));
        let t81 = time_median(reps8, || pl8.gemv(&x));
        assert!(
            t81 < tf1,
            "int8 gemv must beat fused f32 at 1 thread: {t81:.6}s vs {tf1:.6}s"
        );
        // ... and pooled (4), bit-identical to serial while it's at it
        let mut pool4 = ThreadPool::new(4, 16);
        assert_eq!(pl8.gemv_pooled(&x, &pool4), y8, "int8 pooled != serial");
        let tf4 = time_median(reps8, || pl.gemv_pooled(&x, &pool4));
        let t84 = time_median(reps8, || pl8.gemv_pooled(&x, &pool4));
        pool4.shutdown();
        assert!(
            t84 < tf4,
            "int8 gemv must beat fused f32 at 4 threads: {t84:.6}s vs {tf4:.6}s"
        );

        // end-to-end budget: 1-layer synthetic forward, int8 vs f32 MAC
        let fs = ForwardSpec::new(128, 64, 1, 4, 128, 16, 1).expect("forward spec");
        let spec = synth::model_spec(&fs, "int8-bench");
        let weights = synth::synth_weights(&fs, 0xBEAB);
        let opts = QuantizeOptions::new().with_threads(1);
        let payload = quantize(&spec, weights, None, Method::Rtn, &cfg8, &opts)
            .expect("quantize forward payload")
            .export_packed()
            .expect("export payload");
        let m8 = BackendBuilder::new()
            .threads(1)
            .mac(MacMode::Int8)
            .forward(fs.clone(), &payload)
            .expect("int8 forward backend")
            .into_forward()
            .expect("int8 forward model");
        let mf = BackendBuilder::new()
            .threads(1)
            .forward(fs.clone(), &payload)
            .expect("f32 forward backend")
            .into_forward()
            .expect("f32 forward model");
        let toks = synth::synth_tokens(&fs, fs.seq, 0xBEAC);
        let l8 = m8.logits(&toks).expect("int8 logits");
        let lf = mf.logits(&toks).expect("f32 logits");
        let (mut d2, mut b2) = (0.0f64, 0.0f64);
        for (&a, &b) in l8.iter().zip(&lf) {
            d2 += ((a - b) as f64).powi(2);
            b2 += (b as f64).powi(2);
        }
        let relerr = (d2 / b2.max(1e-30)).sqrt();
        assert!(relerr <= 1e-2, "int8 forward logits rel err {relerr:.3e} > 1e-2");
        let ppl8 = perplexity(&m8, &toks).expect("int8 ppl");
        let pplf = perplexity(&mf, &toks).expect("f32 ppl");

        benchlib::header("integer MAC arm (rtn-u4, 512x512)");
        println!(
            "  int8 serial {t81:>9.5}s ({:>11.0} blk/s)   f32 serial {tf1:>9.5}s ({:.2}x)",
            n_blocks8 / t81,
            tf1 / t81
        );
        println!(
            "  int8 pooled {t84:>9.5}s ({:>11.0} blk/s)   f32 pooled {tf4:>9.5}s ({:.2}x)",
            n_blocks8 / t84,
            tf4 / t84
        );
        println!(
            "  forward twin: logit L2 rel {relerr:.2e} (gate 1e-2), \
             ppl int8 {ppl8:.4} vs f32 {pplf:.4} (drift {:.2e})",
            (ppl8 - pplf).abs()
        );
        results.insert("int8-gemv-bps".to_string(), n_blocks8 / t81);
        results.insert("int8-speedup-t1".to_string(), tf1 / t81);
        results.insert("int8-speedup-t4".to_string(), tf4 / t84);
        results.insert("int8-logit-relerr".to_string(), relerr);
        results.insert("int8-ppl-drift".to_string(), (ppl8 - pplf).abs());
    }

    match benchlib::merge_bench_json("perf", "perf_gemv", &results) {
        Ok(path) => println!("\nmerged {} keys into {}", results.len(), path.display()),
        Err(e) => eprintln!("\nBENCH_perf.json not written: {e}"),
    }
}
