"""L1: Pallas MSB dequant-matmul kernel.

Computes ``y = x @ dequant(codes, scales).T`` where the weight matrix is
stored in the paper's MSB form: int8 sign+level codes and per-(row, block)
scale tables (see kernels/ref.py for the exact representation).

TPU mapping (DESIGN.md §Hardware-Adaptation): the kernel tiles M and N on
the grid; each program instance streams the full K stripe of its x / codes
tiles through VMEM, decodes the int8 codes to a bf16/f32 tile in-register
(an L-entry table gather — L <= 8 at 4-bit so the table is VMEM-resident
scratch), and feeds the MXU with a dense ``(bm, K) @ (K, bn)`` product.
Storing codes as int8 is the 4x HBM-traffic saving the paper's storage
analysis targets.

CPU note: lowered with ``interpret=True`` — real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute. interpret mode still goes
through the Pallas machinery (BlockSpec slicing, per-program invocation), so
shape/indexing logic is exercised; numerics are validated against
kernels/ref.py by python/tests/test_kernel.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _msb_matmul_kernel(x_ref, codes_ref, scales_ref, o_ref, *, block: int):
    """One (bm, bn) output tile.

    x_ref:      f32 [bm, K]
    codes_ref:  i8  [bn, K]
    scales_ref: f32 [bn, K // block, L]
    o_ref:      f32 [bm, bn]
    """
    x = x_ref[...]
    codes = codes_ref[...].astype(jnp.int32)
    scales = scales_ref[...]

    bn, k = codes.shape
    lvl = jnp.abs(codes)                      # 0 or 1..L
    sgn = jnp.sign(codes).astype(x.dtype)
    blk = jax.lax.broadcasted_iota(jnp.int32, (bn, k), 1) // block
    # gather scale per element: scales[n, blk, lvl-1]
    l = scales.shape[-1]
    idx = jnp.clip(lvl - 1, 0, l - 1)
    # flatten the (block, level) axes for a single take_along_axis
    flat = scales.reshape(bn, -1)             # [bn, K//block * L]
    w = jnp.take_along_axis(flat, blk * l + idx, axis=1)
    w = sgn * w                               # [bn, K] decoded tile
    o_ref[...] = jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("block", "bm", "bn", "interpret"))
def msb_matmul(
    x: jnp.ndarray,
    codes: jnp.ndarray,
    scales: jnp.ndarray,
    *,
    block: int = 64,
    bm: int = 128,
    bn: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """x [M, K] (f32) @ dequant(codes [N, K] i8, scales [N, K//block, L]).T."""
    m, k = x.shape
    n, k2 = codes.shape
    assert k == k2, (x.shape, codes.shape)
    nb, l = scales.shape[1], scales.shape[2]
    assert nb * block == k, (scales.shape, block, k)

    bm = min(bm, m)
    bn = min(bn, n)
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)

    grid = (m // bm, n // bn)
    return pl.pallas_call(
        functools.partial(_msb_matmul_kernel, block=block),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, k), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, nb, l), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, codes, scales)


def vmem_footprint_bytes(k: int, bm: int, bn: int, block: int, levels: int) -> dict:
    """Static VMEM budget estimate for one program instance (TPU target).

    Used by DESIGN/EXPERIMENTS §Perf: interpret-mode wall-clock is not a TPU
    proxy, so we reason about the schedule structurally.
    """
    x_tile = bm * k * 4
    code_tile = bn * k * 1
    scale_tile = bn * (k // block) * levels * 4
    out_tile = bm * bn * 4
    decoded = bn * k * 4  # the in-register decoded stripe
    total = x_tile + code_tile + scale_tile + out_tile + decoded
    return {
        "x_tile": x_tile,
        "code_tile": code_tile,
        "scale_tile": scale_tile,
        "out_tile": out_tile,
        "decoded_tile": decoded,
        "total": total,
        "fits_16MiB_vmem": total <= 16 * 1024 * 1024,
    }
