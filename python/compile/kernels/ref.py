"""Pure-jnp oracle for the MSB dequant-matmul kernel.

Representation (matches rust/src/msb/codebook.rs):

* weight matrix ``W`` is stored as ``[out, in]`` (a linear layer computes
  ``y = x @ W.T``);
* each output row is split into blocks of ``t`` consecutive input elements;
* a block owns ``L = 2**(b-1)`` positive scales ``alpha_z``;
* each weight is coded as int8 ``c``: ``c == 0`` -> exact zero (kept as a
  zero-loss special group, paper §3.2), else ``w_hat = sign(c) *
  scales[row, k // t, |c| - 1]``.

The oracle is deliberately written with the most obvious jnp ops so that the
Pallas kernel (python/compile/kernels/msb_dequant.py) has an independent
reference to converge against.
"""

from __future__ import annotations

import jax.numpy as jnp


def msb_dequant_ref(codes: jnp.ndarray, scales: jnp.ndarray, block: int) -> jnp.ndarray:
    """Decode int8 MSB codes back to float weights.

    codes:  int8 [N, K]
    scales: f32  [N, K // block, L]
    returns f32 [N, K]
    """
    n, k = codes.shape
    lvl = jnp.abs(codes).astype(jnp.int32)           # 0 (zero) or 1..L
    sgn = jnp.sign(codes).astype(scales.dtype)
    blk = jnp.arange(k) // block                     # [K]
    sc = scales[:, blk, :]                           # [N, K, L]
    idx = jnp.clip(lvl - 1, 0, scales.shape[-1] - 1)
    val = jnp.take_along_axis(sc, idx[..., None], axis=-1)[..., 0]
    return sgn * val


def msb_matmul_ref(
    x: jnp.ndarray, codes: jnp.ndarray, scales: jnp.ndarray, block: int
) -> jnp.ndarray:
    """x [M, K] @ dequant(codes, scales).T -> [M, N]."""
    w = msb_dequant_ref(codes, scales, block)
    return x @ w.T


def msb_quantize_ref(w, block: int, levels: int):
    """A simple *reference* MSB quantizer used only by the python tests: an
    equal-population grouping of |w| per block into ``levels`` groups, each
    group's scale = mean |w| of the group. This is NOT the paper's optimized
    grouping (that lives in rust); it just produces valid (codes, scales)
    pairs for kernel round-trip tests.
    """
    import numpy as np

    w = np.asarray(w, dtype=np.float32)
    n, k = w.shape
    assert k % block == 0
    nb = k // block
    codes = np.zeros((n, k), dtype=np.int8)
    scales = np.zeros((n, nb, levels), dtype=np.float32)
    for r in range(n):
        for b in range(nb):
            seg = w[r, b * block : (b + 1) * block]
            mags = np.abs(seg)
            nz = mags > 0
            nnz = int(nz.sum())
            if nnz == 0:
                continue
            nz_idx = np.flatnonzero(nz)
            order = np.argsort(mags[nz_idx], kind="stable")
            nz_idx = nz_idx[order]
            bounds = np.linspace(0, nnz, levels + 1).astype(int)
            for z in range(levels):
                sel = nz_idx[bounds[z] : bounds[z + 1]]
                if len(sel) == 0:
                    scales[r, b, z] = scales[r, b, z - 1] if z else 0.0
                    continue
                scales[r, b, z] = mags[sel].mean()
                codes[r, b * block + sel] = (np.sign(seg[sel]) * (z + 1)).astype(np.int8)
    return jnp.asarray(codes), jnp.asarray(scales)
