from . import msb_dequant, ref  # noqa: F401
