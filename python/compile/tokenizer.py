"""Char-level tokenizer with a fixed, corpus-independent vocabulary.

A fixed vocab keeps the L2 HLO interface stable across corpus regenerations:
token ids never shift, so previously exported executables stay valid.
"""

from __future__ import annotations

# printable subset that the corpus generators can emit
_ALPHABET = (
    "\n !\"#$%&'()*+,-./0123456789:;<=>?@"
    "ABCDEFGHIJKLMNOPQRSTUVWXYZ[\\]^_`"
    "abcdefghijklmnopqrstuvwxyz{|}~"
)

PAD_ID = 0  # reserved; never produced by encode()


class CharTokenizer:
    def __init__(self) -> None:
        self.itos = ["<pad>"] + list(_ALPHABET)
        self.stoi = {c: i for i, c in enumerate(self.itos)}

    @property
    def vocab_size(self) -> int:
        return len(self.itos)

    def encode(self, text: str) -> list[int]:
        return [self.stoi[c] for c in text if c in self.stoi]

    def decode(self, ids: list[int]) -> str:
        return "".join(self.itos[i] for i in ids if 0 < i < len(self.itos))
