"""Deterministic synthetic corpus + QA probe generators.

The paper evaluates on WikiText-2 / PTB / C4 perplexity and seven zero-shot
QA suites. We have no license-clean copies of those in this offline image,
so we substitute three differently-flavoured synthetic sub-corpora (``wk``:
narrative prose, ``pt``: telegraphic headlines, ``c4``: web boilerplate) and
seven synthetic multiple-choice probe families whose answers are learnable
from the training corpus. The *evaluation mechanism* (perplexity deltas and
argmax-logprob multiple choice) is identical to the paper's; see
DESIGN.md "Substitutions".

Everything is driven by ``random.Random(seed)`` so artifacts are
reproducible bit-for-bit.
"""

from __future__ import annotations

import random
from dataclasses import dataclass, field

NOUNS = [
    "cat", "dog", "bird", "fish", "tree", "river", "stone", "cloud",
    "house", "road", "ship", "star", "field", "horse", "wolf", "crow",
]
ADJS = [
    "old", "small", "quiet", "bright", "dark", "slow", "quick", "cold",
    "warm", "tall", "short", "pale", "loud", "soft", "sharp", "plain",
]
VERBS = [
    "sees", "finds", "follows", "carries", "watches", "passes", "guards",
    "holds", "meets", "leaves", "crosses", "circles", "avoids", "greets",
]
PLACES = ["hill", "lake", "wall", "gate", "bridge", "market", "harbor", "tower"]

# fixed subject -> sound association used by the "agreement" probe family
SOUND_OF = {
    "cat": "purrs", "dog": "barks", "bird": "sings", "wolf": "howls",
    "crow": "caws", "horse": "neighs", "fish": "bubbles", "river": "murmurs",
}

# fixed key -> value table used by the "retrieval" probe family
KV_KEYS = [f"k{i}" for i in range(8)]
KV_VALS = [f"v{i}" for i in range(8)]


def _sentence_wk(rng: random.Random) -> str:
    a, b = rng.choice(ADJS), rng.choice(ADJS)
    n1, n2 = rng.choice(NOUNS), rng.choice(NOUNS)
    v = rng.choice(VERBS)
    p = rng.choice(PLACES)
    return f"the {a} {n1} {v} the {b} {n2} near the {p} ."


def _sentence_pt(rng: random.Random) -> str:
    n1, n2 = rng.choice(NOUNS), rng.choice(NOUNS)
    v = rng.choice(VERBS)
    a = rng.choice(ADJS)
    return f"{n1} {v} {n2} ; {n2} {a} ."


def _sentence_c4(rng: random.Random) -> str:
    n = rng.choice(NOUNS)
    a = rng.choice(ADJS)
    k = rng.randrange(100)
    return f"item {k} : {a} {n} | click here | page {k % 10} of 10 ."


def _pattern_agreement(rng: random.Random) -> str:
    s = rng.choice(list(SOUND_OF))
    return f"the {s} {SOUND_OF[s]} ."


def _pattern_ordering(rng: random.Random) -> str:
    start = rng.randrange(0, 22)
    run = "abcdefghijklmnopqrstuvwxyz"[start : start + 5]
    return " ".join(run) + " ."


def _pattern_copy(rng: random.Random) -> str:
    w = rng.choice(NOUNS)
    return f"{w} {w} {w} {w} ."


def _pattern_arith(rng: random.Random) -> str:
    a = rng.randrange(0, 5)
    b = rng.randrange(0, 5)
    return f"{a} + {b} = {a + b} ."


def _pattern_parity(rng: random.Random) -> str:
    n = rng.randrange(0, 10)
    word = "even" if n % 2 == 0 else "odd"
    return f"{n} is {word} ."


def _pattern_retrieval(rng: random.Random) -> str:
    i = rng.randrange(len(KV_KEYS))
    return f"key {KV_KEYS[i]} value {KV_VALS[i]} . recall {KV_KEYS[i]} gives {KV_VALS[i]} ."


_FLAVOURS = {
    "wk": _sentence_wk,
    "pt": _sentence_pt,
    "c4": _sentence_c4,
}

_PATTERNS = [
    _pattern_agreement,
    _pattern_ordering,
    _pattern_copy,
    _pattern_arith,
    _pattern_parity,
    _pattern_retrieval,
]


def build_corpus(flavour: str, n_sentences: int, seed: int) -> str:
    """One flavoured sub-corpus, with probe-pattern lines interleaved so the
    trained model can score above chance on the QA suites."""
    rng = random.Random((seed, flavour).__hash__() & 0x7FFFFFFF)
    gen = _FLAVOURS[flavour]
    out = []
    for i in range(n_sentences):
        out.append(gen(rng))
        if i % 3 == 2:  # dense pattern supervision
            out.append(_PATTERNS[rng.randrange(len(_PATTERNS))](rng))
    return "\n".join(out) + "\n"


def build_training_corpus(n_sentences_per_flavour: int, seed: int) -> str:
    parts = [build_corpus(f, n_sentences_per_flavour, seed) for f in _FLAVOURS]
    return "".join(parts)


def build_eval_corpora(n_sentences: int, seed: int) -> dict[str, str]:
    """Held-out eval streams; seed offset keeps them disjoint from training."""
    return {f: build_corpus(f, n_sentences, seed + 10_001) for f in _FLAVOURS}


# ----------------------------------------------------------------------------
# QA probes: 7 task families, each a list of (prompt, candidates, answer_idx)
# ----------------------------------------------------------------------------


@dataclass
class Probe:
    prompt: str
    candidates: list[str]
    answer: int


@dataclass
class ProbeSuite:
    name: str
    probes: list[Probe] = field(default_factory=list)


def _distractors(rng: random.Random, pool: list[str], correct: str, k: int) -> list[str]:
    ds = [w for w in pool if w != correct]
    rng.shuffle(ds)
    return ds[:k]


def _mk_probe(rng: random.Random, prompt: str, correct: str, pool: list[str]) -> Probe:
    cands = _distractors(rng, pool, correct, 3) + [correct]
    rng.shuffle(cands)
    return Probe(prompt, cands, cands.index(correct))


def _suite_cloze(rng: random.Random, n: int) -> ProbeSuite:
    s = ProbeSuite("cloze")
    for _ in range(n):
        a, b = rng.choice(ADJS), rng.choice(ADJS)
        n1, n2 = rng.choice(NOUNS), rng.choice(NOUNS)
        v = rng.choice(VERBS)
        p = rng.choice(PLACES)
        prompt = f"the {a} {n1} {v} the {b} {n2} near the"
        s.probes.append(_mk_probe(rng, prompt, f" {p}", [f" {x}" for x in PLACES]))
    return s


def _suite_agreement(rng: random.Random, n: int) -> ProbeSuite:
    s = ProbeSuite("agreement")
    sounds = sorted(set(SOUND_OF.values()))
    for _ in range(n):
        subj = rng.choice(list(SOUND_OF))
        prompt = f"the {subj}"
        s.probes.append(_mk_probe(rng, prompt, f" {SOUND_OF[subj]}", [f" {x}" for x in sounds]))
    return s


def _suite_ordering(rng: random.Random, n: int) -> ProbeSuite:
    s = ProbeSuite("ordering")
    alpha = "abcdefghijklmnopqrstuvwxyz"
    for _ in range(n):
        start = rng.randrange(0, 21)
        prompt = " ".join(alpha[start : start + 4])
        correct = f" {alpha[start + 4]}"
        pool = [f" {c}" for c in alpha]
        s.probes.append(_mk_probe(rng, prompt, correct, pool))
    return s


def _suite_copy(rng: random.Random, n: int) -> ProbeSuite:
    s = ProbeSuite("copy")
    for _ in range(n):
        w = rng.choice(NOUNS)
        prompt = f"{w} {w} {w}"
        s.probes.append(_mk_probe(rng, prompt, f" {w}", [f" {x}" for x in NOUNS]))
    return s


def _suite_arith(rng: random.Random, n: int) -> ProbeSuite:
    s = ProbeSuite("arith")
    digits = [f" {d}" for d in range(10)]
    for _ in range(n):
        a = rng.randrange(0, 5)
        b = rng.randrange(0, 5)
        prompt = f"{a} + {b} ="
        s.probes.append(_mk_probe(rng, prompt, f" {a + b}", digits))
    return s


def _suite_parity(rng: random.Random, n: int) -> ProbeSuite:
    s = ProbeSuite("parity")
    for _ in range(n):
        k = rng.randrange(0, 10)
        prompt = f"{k} is"
        correct = " even" if k % 2 == 0 else " odd"
        s.probes.append(Probe(prompt, [" even", " odd"], 0 if k % 2 == 0 else 1))
    return s


def _suite_retrieval(rng: random.Random, n: int) -> ProbeSuite:
    s = ProbeSuite("retrieval")
    for _ in range(n):
        i = rng.randrange(len(KV_KEYS))
        prompt = f"key {KV_KEYS[i]} value {KV_VALS[i]} . recall {KV_KEYS[i]} gives"
        s.probes.append(_mk_probe(rng, prompt, f" {KV_VALS[i]}", [f" {v}" for v in KV_VALS]))
    return s


_SUITES = [
    _suite_cloze,
    _suite_agreement,
    _suite_ordering,
    _suite_copy,
    _suite_arith,
    _suite_parity,
    _suite_retrieval,
]


def build_probe_suites(n_per_suite: int, seed: int) -> list[ProbeSuite]:
    rng = random.Random(seed + 777)
    return [mk(rng, n_per_suite) for mk in _SUITES]
