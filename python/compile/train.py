"""Build-time training of the three stand-in models (hand-rolled AdamW; the
offline image has no optax). Python never runs at request time — these
weights are exported once to artifacts/ and consumed by the rust coordinator.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from .model import ModelConfig, init_params, nll_loss


def adamw_init(params):
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": zeros, "v": {k: jnp.zeros_like(v) for k, v in params.items()}, "t": 0}


def make_train_step(cfg: ModelConfig, lr: float = 3e-3, wd: float = 0.01,
                    b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8):
    loss_fn = lambda p, toks: nll_loss(cfg, p, toks)

    @jax.jit
    def step(params, opt, toks):
        loss, grads = jax.value_and_grad(loss_fn)(params, toks)
        t = opt["t"] + 1
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, opt["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, opt["v"], grads)
        mh = jax.tree.map(lambda m_: m_ / (1 - b1**t), m)
        vh = jax.tree.map(lambda v_: v_ / (1 - b2**t), v)
        params = jax.tree.map(
            lambda p, mh_, vh_: p - lr * (mh_ / (jnp.sqrt(vh_) + eps) + wd * p),
            params, mh, vh,
        )
        return params, {"m": m, "v": v, "t": t}, loss

    return step


def sample_batch(rng: np.random.Generator, stream: np.ndarray, batch: int, seq: int):
    starts = rng.integers(0, len(stream) - seq - 1, size=batch)
    return jnp.asarray(
        np.stack([stream[s : s + seq + 1] for s in starts]).astype(np.int32)
    )


def train_model(
    cfg: ModelConfig,
    stream: np.ndarray,
    steps: int,
    batch: int = 16,
    seed: int = 0,
    log_every: int = 25,
) -> tuple[dict, list[dict]]:
    params = init_params(cfg, seed)
    opt = adamw_init(params)
    step = make_train_step(cfg)
    rng = np.random.default_rng(seed + 1)
    log: list[dict] = []
    t0 = time.time()
    for it in range(steps):
        toks = sample_batch(rng, stream, batch, cfg.seq)
        params, opt, loss = step(params, opt, toks)
        if it % log_every == 0 or it == steps - 1:
            entry = {"step": it, "loss": float(loss), "elapsed_s": round(time.time() - t0, 2)}
            log.append(entry)
            print(f"  [{cfg.name}] step {it:4d} loss {float(loss):.4f} "
                  f"({entry['elapsed_s']}s)", flush=True)
    return params, log
