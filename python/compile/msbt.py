"""`.msbt` — the tensor container shared between python (writer) and rust
(reader/writer, rust/src/io/msbt.rs). Custom format because the offline
crate set has no npz/serde; the layout is trivially parseable:

    magic   b"MSBT"
    version u32 LE (writer emits 3; reader accepts 1, 2 and 3)
    count   u32 LE
    count * {
        name_len u16 LE, name utf-8,
        dtype    u8   (0=f32, 1=i32, 2=bf16 (u16 payload), 3=i8,
                       4=u4 packed nibbles — v2+,
                       5=u2 / 6=u1 bit-packed codes — v3+),
        ndim     u8,
        dims     ndim * u32 LE,
        nbytes   u64 LE,
        data     raw LE bytes
    }

Format v2 generalized v1's ``nbytes == n * itemsize`` invariant to a
per-dtype byte count (``u4``: two 4-bit codes per byte, low nibble first,
``nbytes == ceil(n / 2)`` with ``n`` the logical element count); v3 adds
the sub-nibble ``u2`` (four codes per byte) and ``u1`` (eight codes per
byte) dtypes so 1/2-bit code payloads stop paying the nibble floor. All
packed dtypes are LSB-first within each byte and surface as
:class:`U4` / :class:`U2` / :class:`U1`.
"""

from __future__ import annotations

import struct

import numpy as np

VERSION = 3

_DTYPES = {
    np.dtype(np.float32): 0,
    np.dtype(np.int32): 1,
    np.dtype(np.uint16): 2,  # bf16 payload
    np.dtype(np.int8): 3,
}
_NP_OF = {v: k for k, v in _DTYPES.items()}


class _PackedBits:
    """Bit-packed codes: logical ``shape`` with ``8 // width`` codes per
    byte (LSB-first) in ``packed`` (uint8, ``ceil(n * width / 8)``
    bytes)."""

    width: int = 0  # set by subclasses
    dtype_code: int = 0
    min_version: int = 3

    def __init__(self, shape, packed):
        self.shape = tuple(int(d) for d in shape)
        self.packed = np.ascontiguousarray(packed, dtype=np.uint8)
        per = 8 // self.width
        n = self.n
        if self.packed.size != (n + per - 1) // per:
            raise ValueError(
                f"u{self.width} {self.shape}: expected {(n + per - 1) // per} "
                f"bytes, got {self.packed.size}")

    @property
    def n(self) -> int:
        return int(np.prod(self.shape, dtype=np.int64)) if self.shape else 1

    def unpack(self) -> np.ndarray:
        """Logical uint8 code array (values 0..2**width) of ``shape``."""
        return unpack_bits(self.packed, self.n, self.width).reshape(self.shape)

    def __eq__(self, other):
        return (type(other) is type(self) and self.shape == other.shape
                and np.array_equal(self.packed, other.packed))


class U4(_PackedBits):
    """Nibble-packed 4-bit codes (two per byte, low nibble first)."""

    width = 4
    dtype_code = 4
    min_version = 2


class U2(_PackedBits):
    """Bit-packed 2-bit codes (four per byte, LSB-first) — v3+."""

    width = 2
    dtype_code = 5


class U1(_PackedBits):
    """Bit-packed 1-bit codes (eight per byte, LSB-first) — v3+."""

    width = 1
    dtype_code = 6


_PACKED_OF = {cls.dtype_code: cls for cls in (U4, U2, U1)}


def pack_bits(codes: np.ndarray, width: int) -> np.ndarray:
    """Pack ``width``-bit values (width in {1, 2, 4}) LSB-first within each
    byte — byte-compatible with rust ``quant::packing::pack_bits``."""
    if width not in (1, 2, 4):
        raise ValueError(f"unsupported pack width {width}")
    flat = np.ascontiguousarray(codes, dtype=np.uint8).reshape(-1)
    if np.any(flat >= (1 << width)):
        raise ValueError(f"u{width} codes must be < {1 << width}")
    per = 8 // width
    pad = (-flat.size) % per
    if pad:
        flat = np.concatenate([flat, np.zeros(pad, np.uint8)])
    flat = flat.reshape(-1, per)
    shifts = np.arange(per, dtype=np.uint8) * width
    return np.bitwise_or.reduce(flat << shifts, axis=1).astype(np.uint8)


def unpack_bits(packed: np.ndarray, n: int, width: int) -> np.ndarray:
    """Inverse of :func:`pack_bits`; ``n`` is the original code count."""
    if width not in (1, 2, 4):
        raise ValueError(f"unsupported pack width {width}")
    packed = np.ascontiguousarray(packed, dtype=np.uint8)
    per = 8 // width
    shifts = np.arange(per, dtype=np.uint8) * width
    mask = (1 << width) - 1
    out = ((packed[:, None] >> shifts) & mask).astype(np.uint8).reshape(-1)
    return out[:n]


def pack_u4(codes: np.ndarray) -> np.ndarray:
    """Pack an array of 4-bit values (0..15) two-per-byte, low nibble
    first — byte-compatible with rust ``quant::packing::pack_nibbles``."""
    return pack_bits(codes, 4)


def unpack_u4(packed: np.ndarray, n: int) -> np.ndarray:
    """Inverse of :func:`pack_u4`; ``n`` is the original code count."""
    return unpack_bits(packed, n, 4)


def write_msbt(path: str, tensors: dict) -> None:
    with open(path, "wb") as f:
        f.write(b"MSBT")
        f.write(struct.pack("<II", VERSION, len(tensors)))
        for name, arr in tensors.items():
            nb = name.encode()
            if len(nb) > 0xFFFF:
                raise ValueError(f"tensor name too long: {len(nb)} bytes")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            if isinstance(arr, _PackedBits):
                f.write(struct.pack("<BB", arr.dtype_code, len(arr.shape)))
                for d in arr.shape:
                    f.write(struct.pack("<I", d))
                raw = arr.packed.tobytes()
            else:
                arr = np.ascontiguousarray(arr)
                if arr.dtype == np.int64:
                    arr = arr.astype(np.int32)
                if arr.dtype == np.float64:
                    arr = arr.astype(np.float32)
                code = _DTYPES[arr.dtype]
                f.write(struct.pack("<BB", code, arr.ndim))
                for d in arr.shape:
                    f.write(struct.pack("<I", d))
                raw = arr.tobytes()
            f.write(struct.pack("<Q", len(raw)))
            f.write(raw)


def read_msbt(path: str) -> dict:
    out: dict = {}
    with open(path, "rb") as f:
        assert f.read(4) == b"MSBT"
        version, count = struct.unpack("<II", f.read(8))
        assert version in (1, 2, 3), f"unsupported msbt version {version}"
        for _ in range(count):
            (nlen,) = struct.unpack("<H", f.read(2))
            name = f.read(nlen).decode()
            code, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            (nbytes,) = struct.unpack("<Q", f.read(8))
            raw = f.read(nbytes)
            if code in _PACKED_OF:
                cls = _PACKED_OF[code]
                assert version >= cls.min_version, \
                    f"dtype {code} requires msbt v{cls.min_version}"
                out[name] = cls(dims, np.frombuffer(raw, np.uint8))
            else:
                out[name] = (np.frombuffer(raw, dtype=_NP_OF[code])
                             .reshape(dims).copy())
    return out
