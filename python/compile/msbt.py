"""`.msbt` — the tensor container shared between python (writer) and rust
(reader, rust/src/io/msbt.rs). Custom format because the offline crate set
has no npz/serde; the layout is trivially parseable:

    magic   b"MSBT"
    version u32 LE (=1)
    count   u32 LE
    count * {
        name_len u16 LE, name utf-8,
        dtype    u8   (0=f32, 1=i32, 2=bf16 (u16 payload), 3=i8),
        ndim     u8,
        dims     ndim * u32 LE,
        nbytes   u64 LE,
        data     raw LE bytes
    }
"""

from __future__ import annotations

import struct

import numpy as np

_DTYPES = {
    np.dtype(np.float32): 0,
    np.dtype(np.int32): 1,
    np.dtype(np.uint16): 2,  # bf16 payload
    np.dtype(np.int8): 3,
}
_NP_OF = {v: k for k, v in _DTYPES.items()}


def write_msbt(path: str, tensors: dict[str, np.ndarray]) -> None:
    with open(path, "wb") as f:
        f.write(b"MSBT")
        f.write(struct.pack("<II", 1, len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr)
            if arr.dtype == np.int64:
                arr = arr.astype(np.int32)
            if arr.dtype == np.float64:
                arr = arr.astype(np.float32)
            code = _DTYPES[arr.dtype]
            nb = name.encode()
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", code, arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            raw = arr.tobytes()
            f.write(struct.pack("<Q", len(raw)))
            f.write(raw)


def read_msbt(path: str) -> dict[str, np.ndarray]:
    out: dict[str, np.ndarray] = {}
    with open(path, "rb") as f:
        assert f.read(4) == b"MSBT"
        version, count = struct.unpack("<II", f.read(8))
        assert version == 1
        for _ in range(count):
            (nlen,) = struct.unpack("<H", f.read(2))
            name = f.read(nlen).decode()
            code, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            (nbytes,) = struct.unpack("<Q", f.read(8))
            raw = f.read(nbytes)
            out[name] = np.frombuffer(raw, dtype=_NP_OF[code]).reshape(dims).copy()
    return out
