"""`.msbt` — the tensor container shared between python (writer) and rust
(reader/writer, rust/src/io/msbt.rs). Custom format because the offline
crate set has no npz/serde; the layout is trivially parseable:

    magic   b"MSBT"
    version u32 LE (writer emits 2; reader accepts 1 and 2)
    count   u32 LE
    count * {
        name_len u16 LE, name utf-8,
        dtype    u8   (0=f32, 1=i32, 2=bf16 (u16 payload), 3=i8,
                       4=u4 packed nibbles — v2 only),
        ndim     u8,
        dims     ndim * u32 LE,
        nbytes   u64 LE,
        data     raw LE bytes
    }

Format v2 generalizes v1's ``nbytes == n * itemsize`` invariant to a
per-dtype byte count: the ``u4`` dtype stores two 4-bit codes per byte
(low nibble first), so ``nbytes == ceil(n / 2)`` with ``n`` the logical
element count (product of dims). U4 tensors surface as :class:`U4`.
"""

from __future__ import annotations

import struct

import numpy as np

VERSION = 2

_DTYPES = {
    np.dtype(np.float32): 0,
    np.dtype(np.int32): 1,
    np.dtype(np.uint16): 2,  # bf16 payload
    np.dtype(np.int8): 3,
}
_NP_OF = {v: k for k, v in _DTYPES.items()}
_U4 = 4


class U4:
    """Nibble-packed 4-bit codes: logical ``shape`` with two codes per
    byte (low nibble first) in ``packed`` (uint8, ``ceil(n/2)`` bytes)."""

    def __init__(self, shape, packed):
        self.shape = tuple(int(d) for d in shape)
        self.packed = np.ascontiguousarray(packed, dtype=np.uint8)
        n = int(np.prod(self.shape, dtype=np.int64)) if self.shape else 1
        if self.packed.size != (n + 1) // 2:
            raise ValueError(f"u4 {self.shape}: expected {(n + 1) // 2} bytes, "
                             f"got {self.packed.size}")

    @property
    def n(self) -> int:
        return int(np.prod(self.shape, dtype=np.int64)) if self.shape else 1

    def unpack(self) -> np.ndarray:
        """Logical uint8 code array (values 0..15) of ``shape``."""
        return unpack_u4(self.packed, self.n).reshape(self.shape)

    def __eq__(self, other):
        return (isinstance(other, U4) and self.shape == other.shape
                and np.array_equal(self.packed, other.packed))


def pack_u4(codes: np.ndarray) -> np.ndarray:
    """Pack an array of 4-bit values (0..15) two-per-byte, low nibble
    first — byte-compatible with rust ``quant::packing::pack_nibbles``."""
    flat = np.ascontiguousarray(codes, dtype=np.uint8).reshape(-1)
    if np.any(flat > 15):
        raise ValueError("u4 codes must be < 16")
    if flat.size % 2:
        flat = np.concatenate([flat, np.zeros(1, np.uint8)])
    return (flat[0::2] | (flat[1::2] << 4)).astype(np.uint8)


def unpack_u4(packed: np.ndarray, n: int) -> np.ndarray:
    """Inverse of :func:`pack_u4`; ``n`` is the original code count."""
    packed = np.ascontiguousarray(packed, dtype=np.uint8)
    out = np.empty(packed.size * 2, np.uint8)
    out[0::2] = packed & 0xF
    out[1::2] = packed >> 4
    return out[:n]


def write_msbt(path: str, tensors: dict) -> None:
    with open(path, "wb") as f:
        f.write(b"MSBT")
        f.write(struct.pack("<II", VERSION, len(tensors)))
        for name, arr in tensors.items():
            nb = name.encode()
            if len(nb) > 0xFFFF:
                raise ValueError(f"tensor name too long: {len(nb)} bytes")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            if isinstance(arr, U4):
                f.write(struct.pack("<BB", _U4, len(arr.shape)))
                for d in arr.shape:
                    f.write(struct.pack("<I", d))
                raw = arr.packed.tobytes()
            else:
                arr = np.ascontiguousarray(arr)
                if arr.dtype == np.int64:
                    arr = arr.astype(np.int32)
                if arr.dtype == np.float64:
                    arr = arr.astype(np.float32)
                code = _DTYPES[arr.dtype]
                f.write(struct.pack("<BB", code, arr.ndim))
                for d in arr.shape:
                    f.write(struct.pack("<I", d))
                raw = arr.tobytes()
            f.write(struct.pack("<Q", len(raw)))
            f.write(raw)


def read_msbt(path: str) -> dict:
    out: dict = {}
    with open(path, "rb") as f:
        assert f.read(4) == b"MSBT"
        version, count = struct.unpack("<II", f.read(8))
        assert version in (1, 2), f"unsupported msbt version {version}"
        for _ in range(count):
            (nlen,) = struct.unpack("<H", f.read(2))
            name = f.read(nlen).decode()
            code, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            (nbytes,) = struct.unpack("<Q", f.read(8))
            raw = f.read(nbytes)
            if code == _U4:
                assert version >= 2, "u4 dtype requires msbt v2"
                out[name] = U4(dims, np.frombuffer(raw, np.uint8))
            else:
                out[name] = (np.frombuffer(raw, dtype=_NP_OF[code])
                             .reshape(dims).copy())
    return out
