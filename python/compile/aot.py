"""AOT build: corpus -> train -> calibrate -> export artifacts/.

Run once via ``make artifacts``. Outputs (all consumed by the rust layer,
never by python at runtime):

    manifest.json            model configs, ABI order, file index
    corpus_tokens.msbt       train excerpt + 3 held-out eval streams
    probes.msbt              7 QA probe suites (flattened int arrays)
    {model}_weights.msbt     trained f32 weights (ABI names)
    {model}_calib.msbt       per-layer Gram matrices H = X^T X for GPTQ
    {model}_fwd.hlo.txt      logits executable, tokens [B, T] + flat weights
    small_fwd_msb.hlo.txt    native MSB path: Pallas kernel on (codes, scales)
    training_log.json        loss curves (EXPERIMENTS.md e2e record)

HLO **text** is the interchange format (not serialized protos): jax >= 0.5
emits 64-bit instruction ids that the crate's xla_extension 0.5.1 rejects;
the text parser reassigns ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import corpus as corpus_mod
from .kernels.ref import msb_quantize_ref
from .model import ModelConfig, forward_flat, forward_msb_flat, model_zoo, param_specs
from .msbt import write_msbt
from .tokenizer import CharTokenizer
from .train import train_model

SEED = 1234
EVAL_BATCH = 8
TRAIN_SENTENCES = 4000
EVAL_SENTENCES = 400
PROBES_PER_SUITE = 100
CALIB_SEQUENCES = 32
MSB_BLOCK = 64
TRAIN_STEPS = {"tiny": 300, "small": 300, "base": 350}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# GPTQ calibration: capture linear-layer inputs, accumulate Gram matrices.
# Reimplements the forward with taps (non-jit) — build-time only, small cost.
# ---------------------------------------------------------------------------

def calib_grams(cfg: ModelConfig, params: dict, toks: np.ndarray) -> dict[str, np.ndarray]:
    from .model import _attention, _rmsnorm  # internals, build-time only

    grams: dict[str, np.ndarray] = {}

    def tap(name: str, x: jnp.ndarray):
        flat = np.asarray(x, dtype=np.float64).reshape(-1, x.shape[-1])
        g = flat.T @ flat
        grams[name] = grams.get(name, 0.0) + g

    def lin(x, w):
        return x @ w.T

    x = params["tok_emb"][jnp.asarray(toks)] + params["pos_emb"][: toks.shape[1]][None]
    for i in range(cfg.layers):
        p = f"layer{i}."
        z1 = _rmsnorm(x, params[p + "ln1_g"])
        for nm in ("wq", "wk", "wv"):
            tap(p + nm, z1)
        # re-run attention but capture the pre-wo activation
        b, t, d = z1.shape
        h_, hd = cfg.heads, cfg.head_dim
        q = lin(z1, params[p + "wq"]).reshape(b, t, h_, hd).transpose(0, 2, 1, 3)
        k = lin(z1, params[p + "wk"]).reshape(b, t, h_, hd).transpose(0, 2, 1, 3)
        v = lin(z1, params[p + "wv"]).reshape(b, t, h_, hd).transpose(0, 2, 1, 3)
        att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(hd)
        mask = jnp.tril(jnp.ones((t, t), bool))
        att = jax.nn.softmax(jnp.where(mask, att, -1e9), axis=-1)
        y = jnp.einsum("bhqk,bhkd->bhqd", att, v).transpose(0, 2, 1, 3).reshape(b, t, d)
        tap(p + "wo", y)
        h = x + lin(y, params[p + "wo"])
        z2 = _rmsnorm(h, params[p + "ln2_g"])
        tap(p + "w_gate", z2)
        tap(p + "w_up", z2)
        mid = jax.nn.silu(lin(z2, params[p + "w_gate"])) * lin(z2, params[p + "w_up"])
        tap(p + "w_down", mid)
        x = h + lin(mid, params[p + "w_down"])
    return {k: v.astype(np.float32) for k, v in grams.items()}


# ---------------------------------------------------------------------------
# Probe flattening
# ---------------------------------------------------------------------------

def flatten_probes(suites, tok: CharTokenizer) -> tuple[dict[str, np.ndarray], list[dict]]:
    tensors: dict[str, np.ndarray] = {}
    meta = []
    for s in suites:
        p_tok, p_off = [], [0]
        c_tok, c_off = [], [0]
        c_cnt, ans = [], []
        for pr in s.probes:
            ids = tok.encode(pr.prompt)
            p_tok += ids
            p_off.append(len(p_tok))
            for c in pr.candidates:
                cids = tok.encode(c)
                c_tok += cids
                c_off.append(len(c_tok))
            c_cnt.append(len(pr.candidates))
            ans.append(pr.answer)
        pre = s.name
        tensors[f"{pre}.prompt_tok"] = np.asarray(p_tok, np.int32)
        tensors[f"{pre}.prompt_off"] = np.asarray(p_off, np.int32)
        tensors[f"{pre}.cand_tok"] = np.asarray(c_tok, np.int32)
        tensors[f"{pre}.cand_off"] = np.asarray(c_off, np.int32)
        tensors[f"{pre}.cand_count"] = np.asarray(c_cnt, np.int32)
        tensors[f"{pre}.answer"] = np.asarray(ans, np.int32)
        meta.append({"name": s.name, "n": len(s.probes)})
    return tensors, meta


# ---------------------------------------------------------------------------
# Main build
# ---------------------------------------------------------------------------

def build(out_dir: str, quick: bool = False) -> None:
    os.makedirs(out_dir, exist_ok=True)
    tok = CharTokenizer()
    t_start = time.time()

    n_train = 400 if quick else TRAIN_SENTENCES
    n_eval = 80 if quick else EVAL_SENTENCES
    steps = {k: (30 if quick else v) for k, v in TRAIN_STEPS.items()}

    print("== corpus ==", flush=True)
    train_text = corpus_mod.build_training_corpus(n_train, SEED)
    eval_texts = corpus_mod.build_eval_corpora(n_eval, SEED)
    train_stream = np.asarray(tok.encode(train_text), np.int32)
    eval_streams = {f: np.asarray(tok.encode(t), np.int32) for f, t in eval_texts.items()}
    print(f"  train tokens: {len(train_stream)}; eval: "
          f"{ {f: len(s) for f, s in eval_streams.items()} }")

    suites = corpus_mod.build_probe_suites(8 if quick else PROBES_PER_SUITE, SEED)
    probe_tensors, probe_meta = flatten_probes(suites, tok)
    write_msbt(os.path.join(out_dir, "probes.msbt"), probe_tensors)

    tokens_out = {"train_excerpt": train_stream[:50_000]}
    for f, s in eval_streams.items():
        tokens_out[f"eval_{f}"] = s
    write_msbt(os.path.join(out_dir, "corpus_tokens.msbt"), tokens_out)

    zoo = model_zoo(tok.vocab_size)
    if quick:
        zoo = zoo[:1]
    manifest: dict = {
        "seed": SEED,
        "vocab": tok.vocab_size,
        "msb_block": MSB_BLOCK,
        "eval_batch": EVAL_BATCH,
        "eval_streams": sorted(f"eval_{f}" for f in eval_streams),
        "probe_suites": probe_meta,
        "models": [],
    }
    training_log = {}

    for cfg in zoo:
        print(f"== train {cfg.name} (d={cfg.d} L={cfg.layers}) ==", flush=True)
        params, log = train_model(cfg, train_stream, steps[cfg.name], seed=SEED)
        training_log[cfg.name] = log

        np_params = {k: np.asarray(v) for k, v in params.items()}
        write_msbt(os.path.join(out_dir, f"{cfg.name}_weights.msbt"), np_params)

        print(f"== calibrate {cfg.name} (GPTQ Grams) ==", flush=True)
        rng = np.random.default_rng(SEED + 7)
        starts = rng.integers(0, len(train_stream) - cfg.seq, CALIB_SEQUENCES)
        calib_toks = np.stack([train_stream[s : s + cfg.seq] for s in starts])
        grams = calib_grams(cfg, params, calib_toks)
        write_msbt(os.path.join(out_dir, f"{cfg.name}_calib.msbt"), grams)

        print(f"== lower {cfg.name}_fwd ==", flush=True)
        tok_spec = jax.ShapeDtypeStruct((EVAL_BATCH, cfg.seq), jnp.int32)
        w_specs = [
            jax.ShapeDtypeStruct(shape, jnp.float32)
            for _, shape, _ in param_specs(cfg)
        ]
        fn = lambda tokens, *flat: (forward_flat(cfg, tokens, *flat),)
        lowered = jax.jit(fn).lower(tok_spec, *w_specs)
        hlo = to_hlo_text(lowered)
        hlo_path = f"{cfg.name}_fwd.hlo.txt"
        with open(os.path.join(out_dir, hlo_path), "w") as f:
            f.write(hlo)
        print(f"  wrote {hlo_path} ({len(hlo)} chars)")

        manifest["models"].append(
            {
                "name": cfg.name,
                "d": cfg.d,
                "layers": cfg.layers,
                "heads": cfg.heads,
                "ff": cfg.ff,
                "seq": cfg.seq,
                "params": [
                    {"name": n, "shape": list(s), "quant": q}
                    for n, s, q in param_specs(cfg)
                ],
                "weights": f"{cfg.name}_weights.msbt",
                "calib": f"{cfg.name}_calib.msbt",
                "fwd_hlo": hlo_path,
            }
        )

    # Native MSB-kernel executable for the `small` model (L1 integration
    # proof): quantizable linears consume (codes, scales) via the Pallas
    # kernel. Skipped in --quick mode (tiny-only zoo).
    kernel_model = next((m for m in zoo if m.name == "small"), None)
    if kernel_model is not None:
        cfg = kernel_model
        print("== lower small_fwd_msb (Pallas MSB kernel path) ==", flush=True)
        specs = param_specs(cfg)
        flat_specs: list[jax.ShapeDtypeStruct] = []
        for n, s, q in specs:
            if not q:
                flat_specs.append(jax.ShapeDtypeStruct(s, jnp.float32))
        levels = 8  # 4-bit: 2^(b-1)
        for n, s, q in specs:
            if q:
                out_d, in_d = s
                flat_specs.append(jax.ShapeDtypeStruct((out_d, in_d), jnp.int8))
                flat_specs.append(
                    jax.ShapeDtypeStruct((out_d, in_d // MSB_BLOCK, levels), jnp.float32)
                )
        tok_spec = jax.ShapeDtypeStruct((4, cfg.seq), jnp.int32)
        fn = lambda tokens, *flat: (forward_msb_flat(cfg, MSB_BLOCK, tokens, *flat),)
        lowered = jax.jit(fn).lower(tok_spec, *flat_specs)
        hlo = to_hlo_text(lowered)
        with open(os.path.join(out_dir, "small_fwd_msb.hlo.txt"), "w") as f:
            f.write(hlo)
        print(f"  wrote small_fwd_msb.hlo.txt ({len(hlo)} chars)")
        manifest["msb_kernel_model"] = {
            "name": "small",
            "hlo": "small_fwd_msb.hlo.txt",
            "batch": 4,
            "levels": levels,
        }

    with open(os.path.join(out_dir, "training_log.json"), "w") as f:
        json.dump(training_log, f, indent=1)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"== artifacts complete in {time.time() - t_start:.1f}s ==")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="tiny-only, few steps; for CI smoke")
    args = ap.parse_args()
    build(args.out, quick=args.quick)


if __name__ == "__main__":
    main()
