"""L2: GPT-style decoder-only transformer in JAX (build-time only).

Design points driven by the reproduction:

* Weight matrices are stored ``[out, in]`` and linears compute
  ``y = x @ W.T`` — the exact orientation the rust quantizers and the L1
  Pallas kernel assume (64-element groups run along ``in`` within a row).
* ``forward(tokens, *flat_weights)`` takes the weights as *runtime
  arguments*, so a single AOT-lowered executable serves both the
  full-precision model and every simulated-quantization variant: rust
  dequantizes to f32 and feeds the same executable (paper §4.1 "All
  quantized values are decoded and stored in bfloat16" — we decode to f32
  through a bf16 round-trip on the rust side).
* ``forward_msb`` swaps every quantizable linear for the Pallas MSB kernel
  taking (codes, scales) pairs — the native-representation execution path.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.msb_dequant import msb_matmul


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d: int
    layers: int
    heads: int
    ff: int
    seq: int  # train/eval context length

    @property
    def head_dim(self) -> int:
        return self.d // self.heads


# the three "model family" stand-ins (DESIGN.md "Substitutions")
def model_zoo(vocab: int) -> list[ModelConfig]:
    return [
        ModelConfig("tiny", vocab, d=64, layers=2, heads=2, ff=256, seq=96),
        ModelConfig("small", vocab, d=128, layers=3, heads=4, ff=512, seq=96),
        ModelConfig("base", vocab, d=192, layers=4, heads=6, ff=768, seq=96),
    ]


# ---------------------------------------------------------------------------
# Parameters. Stable name order defines the flat-argument ABI of the HLO.
# ---------------------------------------------------------------------------

def param_specs(cfg: ModelConfig) -> list[tuple[str, tuple[int, ...], bool]]:
    """(name, shape, quantizable) in ABI order."""
    specs: list[tuple[str, tuple[int, ...], bool]] = [
        ("tok_emb", (cfg.vocab, cfg.d), False),
        ("pos_emb", (cfg.seq, cfg.d), False),
    ]
    for i in range(cfg.layers):
        p = f"layer{i}."
        specs += [
            (p + "ln1_g", (cfg.d,), False),
            (p + "wq", (cfg.d, cfg.d), True),
            (p + "wk", (cfg.d, cfg.d), True),
            (p + "wv", (cfg.d, cfg.d), True),
            (p + "wo", (cfg.d, cfg.d), True),
            (p + "ln2_g", (cfg.d,), False),
            (p + "w_gate", (cfg.ff, cfg.d), True),
            (p + "w_up", (cfg.ff, cfg.d), True),
            (p + "w_down", (cfg.d, cfg.ff), True),
        ]
    specs.append(("ln_f_g", (cfg.d,), False))
    return specs


def init_params(cfg: ModelConfig, seed: int) -> dict[str, jnp.ndarray]:
    rng = np.random.default_rng(seed)
    params = {}
    for name, shape, _ in param_specs(cfg):
        if name.endswith("_g"):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name in ("tok_emb", "pos_emb"):
            params[name] = jnp.asarray(
                rng.standard_normal(shape).astype(np.float32) * 0.02
            )
        else:
            fan_in = shape[1]
            params[name] = jnp.asarray(
                rng.standard_normal(shape).astype(np.float32) / np.sqrt(fan_in)
            )
    return params


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def _rmsnorm(x: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6) * g


def _linear(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    return x @ w.T  # w is [out, in]


def _attention(cfg: ModelConfig, x, wq, wk, wv, wo, lin):
    b, t, d = x.shape
    h, hd = cfg.heads, cfg.head_dim
    q = lin(x, wq).reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    k = lin(x, wk).reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    v = lin(x, wv).reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(hd)
    mask = jnp.tril(jnp.ones((t, t), bool))
    att = jnp.where(mask, att, -1e9)
    att = jax.nn.softmax(att, axis=-1)
    y = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    y = y.transpose(0, 2, 1, 3).reshape(b, t, d)
    return lin(y, wo)


def _block(cfg: ModelConfig, x, p, i, lin):
    g = lambda s: p[f"layer{i}.{s}"]
    h = x + _attention(
        cfg, _rmsnorm(x, g("ln1_g")), g("wq"), g("wk"), g("wv"), g("wo"), lin
    )
    z = _rmsnorm(h, g("ln2_g"))
    mlp = lin(jax.nn.silu(lin(z, g("w_gate"))) * lin(z, g("w_up")), g("w_down"))
    return h + mlp


def forward(cfg: ModelConfig, params: dict[str, jnp.ndarray], tokens: jnp.ndarray):
    """tokens [B, T] int32 -> logits [B, T, V] f32. Head tied to tok_emb."""
    b, t = tokens.shape
    x = params["tok_emb"][tokens] + params["pos_emb"][:t][None]
    for i in range(cfg.layers):
        x = _block(cfg, x, params, i, _linear)
    x = _rmsnorm(x, params["ln_f_g"])
    return x @ params["tok_emb"].T


def forward_flat(cfg: ModelConfig, tokens: jnp.ndarray, *flat):
    """ABI entrypoint: weights in param_specs() order. This is what aot.py
    lowers; rust marshals literals in the same order."""
    names = [n for n, _, _ in param_specs(cfg)]
    return forward(cfg, dict(zip(names, flat)), tokens)


# ---------------------------------------------------------------------------
# Native MSB execution path (L1 kernel integration)
# ---------------------------------------------------------------------------

def forward_msb(
    cfg: ModelConfig,
    params: dict[str, jnp.ndarray],
    qparams: dict[str, tuple[jnp.ndarray, jnp.ndarray]],
    tokens: jnp.ndarray,
    block: int = 64,
):
    """Forward where quantizable linears run the Pallas MSB kernel on
    (codes, scales); non-quantizable params stay f32 from ``params``."""

    def lin(x, w_name_or_arr):
        # dispatched by identity: quantized layers pass their name
        if isinstance(w_name_or_arr, str):
            codes, scales = qparams[w_name_or_arr]
            shp = x.shape
            x2 = x.reshape(-1, shp[-1])
            m = x2.shape[0]
            bm = m if m < 128 else 128
            # pad rows so M % bm == 0
            pad = (-m) % bm
            if pad:
                x2 = jnp.concatenate([x2, jnp.zeros((pad, shp[-1]), x2.dtype)])
            n = codes.shape[0]
            bn = n if n < 128 else 128
            y = msb_matmul(x2, codes, scales, block=block, bm=bm, bn=bn)
            if pad:
                y = y[:m]
            return y.reshape(*shp[:-1], n)
        return x @ w_name_or_arr.T

    b, t = tokens.shape
    x = params["tok_emb"][tokens] + params["pos_emb"][:t][None]
    for i in range(cfg.layers):
        p = f"layer{i}."
        named = {
            k: (p + k if (p + k) in qparams else params[p + k])
            for k in ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down")
        }
        g = lambda s: params[p + s]
        h = x + _attention(
            cfg, _rmsnorm(x, g("ln1_g")),
            named["wq"], named["wk"], named["wv"], named["wo"], lin,
        )
        z = _rmsnorm(h, g("ln2_g"))
        mlp = lin(jax.nn.silu(lin(z, named["w_gate"])) * lin(z, named["w_up"]),
                  named["w_down"])
        x = h + mlp
    x = _rmsnorm(x, params["ln_f_g"])
    return x @ params["tok_emb"].T


def forward_msb_flat(cfg: ModelConfig, block: int, tokens: jnp.ndarray, *flat):
    """ABI entrypoint for the MSB-kernel executable: non-quantizable params
    first (in spec order), then (codes, scales) pairs for each quantizable
    matrix (in spec order)."""
    specs = param_specs(cfg)
    params, qparams = {}, {}
    it = iter(flat)
    for name, _, quant in specs:
        if not quant:
            params[name] = next(it)
    for name, _, quant in specs:
        if quant:
            qparams[name] = (next(it), next(it))
    return forward_msb(cfg, params, qparams, tokens, block=block)


# ---------------------------------------------------------------------------
# Training loss
# ---------------------------------------------------------------------------

def nll_loss(cfg: ModelConfig, params, tokens):
    """Mean next-token NLL over [B, T] tokens."""
    logits = forward(cfg, params, tokens[:, :-1])
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    picked = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -picked.mean()
