"""`.msbt` container: python round-trip + byte-layout golden checks (the rust
reader parses the same bytes; the golden test pins the layout)."""

import struct

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.msbt import read_msbt, write_msbt


def test_roundtrip_basic(tmp_path):
    p = tmp_path / "t.msbt"
    tensors = {
        "a": np.arange(12, dtype=np.float32).reshape(3, 4),
        "b.codes": (np.arange(8) - 4).astype(np.int8),
        "c": np.asarray([[1, 2], [3, 4]], np.int32),
        "scalar": np.asarray(7, np.int32),
    }
    write_msbt(str(p), tensors)
    back = read_msbt(str(p))
    assert set(back) == set(tensors)
    for k in tensors:
        np.testing.assert_array_equal(back[k], tensors[k])
        assert back[k].dtype == tensors[k].dtype


@settings(max_examples=15, deadline=None)
@given(
    shape=st.lists(st.integers(1, 5), min_size=0, max_size=3),
    dtype=st.sampled_from([np.float32, np.int32, np.int8, np.uint16]),
    seed=st.integers(0, 1000),
)
def test_roundtrip_hypothesis(tmp_path_factory, shape, dtype, seed):
    rng = np.random.default_rng(seed)
    arr = (rng.standard_normal(shape) * 10).astype(dtype)
    p = tmp_path_factory.mktemp("msbt") / "h.msbt"
    write_msbt(str(p), {"x": arr})
    back = read_msbt(str(p))["x"]
    np.testing.assert_array_equal(back, arr)


def test_byte_layout_golden(tmp_path):
    """Pin the exact on-disk layout the rust reader assumes."""
    p = tmp_path / "g.msbt"
    write_msbt(str(p), {"ab": np.asarray([1.0], np.float32)})
    raw = p.read_bytes()
    assert raw[:4] == b"MSBT"
    version, count = struct.unpack_from("<II", raw, 4)
    assert (version, count) == (1, 1)
    nlen = struct.unpack_from("<H", raw, 12)[0]
    assert nlen == 2 and raw[14:16] == b"ab"
    dtype, ndim = struct.unpack_from("<BB", raw, 16)
    assert (dtype, ndim) == (0, 1)
    dim0 = struct.unpack_from("<I", raw, 18)[0]
    assert dim0 == 1
    nbytes = struct.unpack_from("<Q", raw, 22)[0]
    assert nbytes == 4
    assert struct.unpack_from("<f", raw, 30)[0] == 1.0


def test_int64_float64_are_downcast(tmp_path):
    p = tmp_path / "d.msbt"
    write_msbt(str(p), {"i": np.asarray([1, 2], np.int64), "f": np.asarray([1.5], np.float64)})
    back = read_msbt(str(p))
    assert back["i"].dtype == np.int32
    assert back["f"].dtype == np.float32
