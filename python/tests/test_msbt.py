"""`.msbt` container: python round-trip + byte-layout golden checks (the rust
reader parses the same bytes; the golden tests pin the v2 layout and the v1
back-compat path)."""

import struct

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.msbt import (U1, U2, U4, pack_bits, pack_u4, read_msbt,
                          unpack_bits, unpack_u4, write_msbt)


def test_roundtrip_basic(tmp_path):
    p = tmp_path / "t.msbt"
    tensors = {
        "a": np.arange(12, dtype=np.float32).reshape(3, 4),
        "b.codes": (np.arange(8) - 4).astype(np.int8),
        "c": np.asarray([[1, 2], [3, 4]], np.int32),
        "scalar": np.asarray(7, np.int32),
    }
    write_msbt(str(p), tensors)
    back = read_msbt(str(p))
    assert set(back) == set(tensors)
    for k in tensors:
        np.testing.assert_array_equal(back[k], tensors[k])
        assert back[k].dtype == tensors[k].dtype


@settings(max_examples=15, deadline=None)
@given(
    shape=st.lists(st.integers(1, 5), min_size=0, max_size=3),
    dtype=st.sampled_from([np.float32, np.int32, np.int8, np.uint16]),
    seed=st.integers(0, 1000),
)
def test_roundtrip_hypothesis(tmp_path_factory, shape, dtype, seed):
    rng = np.random.default_rng(seed)
    arr = (rng.standard_normal(shape) * 10).astype(dtype)
    p = tmp_path_factory.mktemp("msbt") / "h.msbt"
    write_msbt(str(p), {"x": arr})
    back = read_msbt(str(p))["x"]
    np.testing.assert_array_equal(back, arr)


def test_u4_pack_unpack():
    codes = np.asarray([1, 15, 0, 7, 9], np.uint8)
    packed = pack_u4(codes)
    np.testing.assert_array_equal(packed, [0xF1, 0x70, 0x09])
    np.testing.assert_array_equal(unpack_u4(packed, 5), codes)
    with pytest.raises(ValueError):
        pack_u4(np.asarray([16], np.uint8))


def test_u4_roundtrip(tmp_path):
    rng = np.random.default_rng(3)
    codes = rng.integers(0, 16, size=(6, 10), dtype=np.uint8)
    t = U4(codes.shape, pack_u4(codes))
    p = tmp_path / "u.msbt"
    write_msbt(str(p), {"layer.codes": t, "plain": np.ones(3, np.float32)})
    back = read_msbt(str(p))
    got = back["layer.codes"]
    assert isinstance(got, U4)
    assert got == t
    np.testing.assert_array_equal(got.unpack(), codes)
    np.testing.assert_array_equal(back["plain"], np.ones(3, np.float32))


def test_byte_layout_golden(tmp_path):
    """Pin the exact v3 on-disk layout the rust reader assumes."""
    p = tmp_path / "g.msbt"
    write_msbt(str(p), {"ab": np.asarray([1.0], np.float32)})
    raw = p.read_bytes()
    assert raw[:4] == b"MSBT"
    version, count = struct.unpack_from("<II", raw, 4)
    assert (version, count) == (3, 1)
    nlen = struct.unpack_from("<H", raw, 12)[0]
    assert nlen == 2 and raw[14:16] == b"ab"
    dtype, ndim = struct.unpack_from("<BB", raw, 16)
    assert (dtype, ndim) == (0, 1)
    dim0 = struct.unpack_from("<I", raw, 18)[0]
    assert dim0 == 1
    nbytes = struct.unpack_from("<Q", raw, 22)[0]
    assert nbytes == 4
    assert struct.unpack_from("<f", raw, 30)[0] == 1.0


def test_u4_byte_layout_golden(tmp_path):
    """Pin the u4 record: logical dims, nbytes == ceil(n/2)."""
    p = tmp_path / "u4.msbt"
    write_msbt(str(p), {"c": U4((5,), np.asarray([0xF1, 0x70, 0x09], np.uint8))})
    raw = p.read_bytes()
    assert struct.unpack_from("<I", raw, 4)[0] == 3
    dtype, ndim = struct.unpack_from("<BB", raw, 15)
    assert (dtype, ndim) == (4, 1)
    assert struct.unpack_from("<I", raw, 17)[0] == 5  # logical count
    assert struct.unpack_from("<Q", raw, 21)[0] == 3  # packed bytes
    assert raw[29:32] == bytes([0xF1, 0x70, 0x09])


def test_bit_pack_goldens():
    # LSB-first within each byte, byte-compatible with rust pack_bits
    np.testing.assert_array_equal(
        pack_bits(np.asarray([1, 0, 0, 1, 0, 1, 1, 0], np.uint8), 1), [0b0110_1001])
    np.testing.assert_array_equal(
        pack_bits(np.asarray([1, 1, 1], np.uint8), 1), [0b0000_0111])
    np.testing.assert_array_equal(
        pack_bits(np.asarray([3, 0, 2, 1], np.uint8), 2), [0b0110_0011])
    with pytest.raises(ValueError):
        pack_bits(np.asarray([2], np.uint8), 1)
    for width in (1, 2, 4):
        rng = np.random.default_rng(width)
        codes = rng.integers(0, 1 << width, size=37, dtype=np.uint8)
        np.testing.assert_array_equal(
            unpack_bits(pack_bits(codes, width), 37, width), codes)


def test_sub_nibble_roundtrip(tmp_path):
    rng = np.random.default_rng(7)
    crumbs = rng.integers(0, 4, size=(3, 10), dtype=np.uint8)
    bits = rng.integers(0, 2, size=(26,), dtype=np.uint8)
    p = tmp_path / "sub.msbt"
    write_msbt(str(p), {
        "w.codes2": U2(crumbs.shape, pack_bits(crumbs, 2)),
        "w.codes1": U1(bits.shape, pack_bits(bits, 1)),
    })
    back = read_msbt(str(p))
    assert isinstance(back["w.codes2"], U2)
    assert isinstance(back["w.codes1"], U1)
    np.testing.assert_array_equal(back["w.codes2"].unpack(), crumbs)
    np.testing.assert_array_equal(back["w.codes1"].unpack(), bits)
    # u1 nbytes = ceil(26/8) = 4
    assert back["w.codes1"].packed.size == 4


def test_v2_rejects_sub_nibble(tmp_path):
    for dtype in (5, 6):
        raw = b"MSBT" + struct.pack("<II", 2, 1)
        raw += struct.pack("<H", 1) + b"c"
        raw += struct.pack("<BB", dtype, 1) + struct.pack("<I", 4)
        raw += struct.pack("<Q", 1) + bytes([0x1B])
        p = tmp_path / f"bad{dtype}.msbt"
        p.write_bytes(raw)
        with pytest.raises(AssertionError):
            read_msbt(str(p))


def test_reads_v1_files(tmp_path):
    """Hand-built v1 bytes (the pre-u4 format) must keep reading."""
    raw = b"MSBT" + struct.pack("<II", 1, 1)
    raw += struct.pack("<H", 2) + b"ab"
    raw += struct.pack("<BB", 0, 1) + struct.pack("<I", 2)
    raw += struct.pack("<Q", 8) + struct.pack("<ff", 1.5, -2.0)
    p = tmp_path / "v1.msbt"
    p.write_bytes(raw)
    back = read_msbt(str(p))
    np.testing.assert_array_equal(back["ab"], np.asarray([1.5, -2.0], np.float32))


def test_v1_rejects_u4(tmp_path):
    raw = b"MSBT" + struct.pack("<II", 1, 1)
    raw += struct.pack("<H", 1) + b"c"
    raw += struct.pack("<BB", 4, 1) + struct.pack("<I", 2)
    raw += struct.pack("<Q", 1) + bytes([0x21])
    p = tmp_path / "bad.msbt"
    p.write_bytes(raw)
    with pytest.raises(AssertionError):
        read_msbt(str(p))


def test_int64_float64_are_downcast(tmp_path):
    p = tmp_path / "d.msbt"
    write_msbt(str(p), {"i": np.asarray([1, 2], np.int64), "f": np.asarray([1.5], np.float64)})
    back = read_msbt(str(p))
    assert back["i"].dtype == np.int32
    assert back["f"].dtype == np.float32
