"""L2 model: shapes, ABI stability, training smoke, MSB-path equivalence."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.kernels.ref import msb_quantize_ref
from compile.model import (
    ModelConfig,
    forward,
    forward_flat,
    forward_msb,
    init_params,
    model_zoo,
    nll_loss,
    param_specs,
)
from compile.tokenizer import CharTokenizer

CFG = ModelConfig("test", vocab=97, d=32, layers=2, heads=2, ff=64, seq=32)


def test_param_specs_abi_is_stable():
    names = [n for n, _, _ in param_specs(CFG)]
    assert names[0] == "tok_emb" and names[1] == "pos_emb"
    assert names[-1] == "ln_f_g"
    assert names.count("layer0.wq") == 1
    # quantizable = exactly the 7 projection matrices per layer
    quant = [n for n, _, q in param_specs(CFG) if q]
    assert len(quant) == 7 * CFG.layers
    assert all(s[1][0] > 0 for s in param_specs(CFG) if len(s[1]) > 1)


def test_forward_shapes_and_determinism():
    params = init_params(CFG, 0)
    toks = jnp.arange(2 * 16, dtype=jnp.int32).reshape(2, 16) % CFG.vocab
    logits = forward(CFG, params, toks)
    assert logits.shape == (2, 16, CFG.vocab)
    logits2 = forward(CFG, params, toks)
    np.testing.assert_array_equal(np.asarray(logits), np.asarray(logits2))


def test_forward_flat_matches_dict():
    params = init_params(CFG, 0)
    toks = jnp.ones((1, 8), jnp.int32)
    flat = [params[n] for n, _, _ in param_specs(CFG)]
    a = forward(CFG, params, toks)
    b = forward_flat(CFG, toks, *flat)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_causality():
    """Changing a future token must not change past logits."""
    params = init_params(CFG, 0)
    t1 = jnp.ones((1, 16), jnp.int32)
    t2 = t1.at[0, 10].set(5)
    l1 = forward(CFG, params, t1)
    l2 = forward(CFG, params, t2)
    np.testing.assert_allclose(np.asarray(l1[0, :10]), np.asarray(l2[0, :10]), atol=1e-5)
    assert not np.allclose(np.asarray(l1[0, 10:]), np.asarray(l2[0, 10:]))


def test_loss_decreases_smoke():
    from compile.train import adamw_init, make_train_step

    params = init_params(CFG, 0)
    step = make_train_step(CFG, lr=1e-2)
    opt = adamw_init(params)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(1, 30, (8, CFG.seq + 1)).astype(np.int32))
    first = None
    for _ in range(30):
        params, opt, loss = step(params, opt, toks)
        first = first if first is not None else float(loss)
    assert float(loss) < first * 0.7, (first, float(loss))


def test_msb_forward_matches_dense_on_dequant():
    """forward_msb(codes, scales) == forward(dequantized weights): the
    native-representation path and the simulated path must agree."""
    cfg = ModelConfig("t2", vocab=97, d=64, layers=1, heads=2, ff=128, seq=16)
    params = init_params(cfg, 1)
    toks = jnp.asarray(np.arange(16, dtype=np.int32)[None] % 90)

    from compile.kernels.ref import msb_dequant_ref

    qparams, dq = {}, dict(params)
    for n, shape, q in param_specs(cfg):
        if q:
            codes, scales = msb_quantize_ref(np.asarray(params[n]), 64, 8)
            qparams[n] = (codes, scales)
            dq[n] = msb_dequant_ref(codes, scales, 64)
    ref = forward(cfg, dq, toks)
    out = forward_msb(cfg, params, qparams, toks, block=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


def test_model_zoo_sizes_increase():
    zoo = model_zoo(97)
    counts = []
    for cfg in zoo:
        n = sum(int(np.prod(s)) for _, s, _ in param_specs(cfg))
        counts.append(n)
    assert counts == sorted(counts)
    assert counts[0] > 50_000  # non-trivial models


def test_nll_loss_near_uniform_at_init():
    params = init_params(CFG, 0)
    toks = jnp.asarray(np.random.default_rng(0).integers(1, 97, (4, 33)).astype(np.int32))
    loss = float(nll_loss(CFG, params, toks))
    assert abs(loss - np.log(97)) < 0.5
