"""Pallas MSB kernel vs pure-jnp oracle — the core L1 correctness signal.

hypothesis sweeps shapes / block sizes / level counts / tile sizes;
assert_allclose against kernels/ref.py throughout.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.msb_dequant import msb_matmul, vmem_footprint_bytes
from compile.kernels.ref import msb_dequant_ref, msb_matmul_ref, msb_quantize_ref


def _mk(rng, m, n, k, block, levels):
    w = rng.standard_normal((n, k)).astype(np.float32)
    x = rng.standard_normal((m, k)).astype(np.float32)
    codes, scales = msb_quantize_ref(w, block=block, levels=levels)
    return jnp.asarray(x), codes, scales


@settings(max_examples=20, deadline=None)
@given(
    m=st.sampled_from([8, 16, 32]),
    n=st.sampled_from([16, 32, 64]),
    kb=st.sampled_from([1, 2, 4]),
    block=st.sampled_from([8, 16, 64]),
    levels=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**16),
)
def test_kernel_matches_ref_sweep(m, n, kb, block, levels, seed):
    k = kb * block
    rng = np.random.default_rng(seed)
    x, codes, scales = _mk(rng, m, n, k, block, levels)
    ref = msb_matmul_ref(x, codes, scales, block)
    out = msb_matmul(x, codes, scales, block=block, bm=m, bn=n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    bm=st.sampled_from([8, 16, 32]),
    bn=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**16),
)
def test_kernel_tiling_invariance(bm, bn, seed):
    """Output must not depend on the (bm, bn) grid decomposition."""
    rng = np.random.default_rng(seed)
    x, codes, scales = _mk(rng, 32, 64, 128, 64, 8)
    full = msb_matmul(x, codes, scales, block=64, bm=32, bn=64)
    tiled = msb_matmul(x, codes, scales, block=64, bm=bm, bn=bn)
    np.testing.assert_allclose(np.asarray(tiled), np.asarray(full), rtol=1e-5, atol=1e-5)


def test_exact_zero_codes_decode_to_zero():
    codes = jnp.zeros((4, 64), jnp.int8)
    scales = jnp.ones((4, 1, 8), jnp.float32)
    w = msb_dequant_ref(codes, scales, 64)
    assert float(jnp.abs(w).max()) == 0.0
    x = jnp.ones((8, 64), jnp.float32)
    out = msb_matmul(x, codes, scales, block=64, bm=8, bn=4)
    assert float(jnp.abs(out).max()) == 0.0


def test_sign_structure():
    """ŵ = sign(c) * α_z exactly — binary sign with multi-scale magnitude."""
    rng = np.random.default_rng(0)
    w = rng.standard_normal((8, 64)).astype(np.float32)
    codes, scales = msb_quantize_ref(w, block=64, levels=8)
    deq = np.asarray(msb_dequant_ref(codes, scales, 64))
    nz = np.asarray(codes) != 0
    assert (np.sign(deq[nz]) == np.sign(np.asarray(codes)[nz])).all()
    # every decoded magnitude must be one of the block's scales
    mags = np.unique(np.abs(deq[nz]).round(6))
    allowed = np.unique(np.asarray(scales).round(6))
    assert set(mags) <= set(allowed)


def test_dequant_mse_decreases_with_levels():
    rng = np.random.default_rng(3)
    w = rng.standard_normal((32, 128)).astype(np.float32)
    errs = []
    for levels in (1, 2, 4, 8):
        codes, scales = msb_quantize_ref(w, block=64, levels=levels)
        deq = np.asarray(msb_dequant_ref(codes, scales, 64))
        errs.append(float(((deq - w) ** 2).sum()))
    assert errs == sorted(errs, reverse=True), errs


def test_vmem_footprint_model():
    est = vmem_footprint_bytes(k=2048, bm=128, bn=128, block=64, levels=8)
    assert est["fits_16MiB_vmem"]
    # int8 codes are 4x smaller than f32 for the same tile
    assert est["code_tile"] * 4 == est["decoded_tile"]
