"""AOT lowering: HLO text is parseable-looking, has the right entry arity,
and the calibration Grams are symmetric PSD. Uses a throwaway tiny config so
the test is fast and independent of artifacts/."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.aot import calib_grams, to_hlo_text
from compile.model import ModelConfig, forward_flat, init_params, param_specs

CFG = ModelConfig("hlo_t", vocab=97, d=32, layers=1, heads=2, ff=64, seq=16)


def _lower():
    tok_spec = jax.ShapeDtypeStruct((2, CFG.seq), jnp.int32)
    w_specs = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s, _ in param_specs(CFG)]
    fn = lambda tokens, *flat: (forward_flat(CFG, tokens, *flat),)
    return jax.jit(fn).lower(tok_spec, *w_specs)


def test_hlo_text_structure():
    text = to_hlo_text(_lower())
    assert "HloModule" in text
    assert "ENTRY" in text
    # one parameter per weight + tokens
    n_params = len(param_specs(CFG)) + 1
    assert text.count("parameter(") >= n_params
    # logits shape appears in the ROOT tuple
    assert f"f32[2,{CFG.seq},97]" in text


def test_hlo_deterministic():
    assert to_hlo_text(_lower()) == to_hlo_text(_lower())


def test_calib_grams_properties():
    params = init_params(CFG, 0)
    toks = np.random.default_rng(0).integers(1, 97, (4, CFG.seq)).astype(np.int32)
    grams = calib_grams(CFG, params, toks)
    quant_names = [n for n, _, q in param_specs(CFG) if q]
    assert set(grams) == set(quant_names)
    for name, h in grams.items():
        in_dim = dict((n, s) for n, s, _ in param_specs(CFG))[name][1]
        assert h.shape == (in_dim, in_dim)
        np.testing.assert_allclose(h, h.T, rtol=1e-4, atol=1e-4)
        eig = np.linalg.eigvalsh(h.astype(np.float64))
        assert eig.min() > -1e-3  # PSD up to float noise
