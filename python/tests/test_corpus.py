"""Corpus and probe generators: determinism, vocab coverage, disjointness,
and probe well-formedness."""

import pytest

from compile import corpus
from compile.tokenizer import CharTokenizer


def test_corpus_deterministic():
    a = corpus.build_training_corpus(50, 1234)
    b = corpus.build_training_corpus(50, 1234)
    assert a == b
    c = corpus.build_training_corpus(50, 999)
    assert a != c


def test_eval_disjoint_from_train_seed():
    train = corpus.build_corpus("wk", 100, 1234)
    evals = corpus.build_eval_corpora(100, 1234)
    assert evals["wk"] != train
    assert set(evals) == {"wk", "pt", "c4"}


def test_flavours_differ():
    evals = corpus.build_eval_corpora(50, 1)
    assert evals["wk"] != evals["pt"] != evals["c4"]


def test_tokenizer_covers_corpus():
    tok = CharTokenizer()
    text = corpus.build_training_corpus(200, 7)
    ids = tok.encode(text)
    assert len(ids) == len(text), "corpus contains chars outside the fixed vocab"
    assert tok.decode(ids) == text


def test_tokenizer_roundtrip_and_pad():
    tok = CharTokenizer()
    assert tok.stoi["a"] > 0
    assert tok.decode([0]) == ""  # pad never decodes
    s = "the old cat sees ."
    assert tok.decode(tok.encode(s)) == s


def test_probe_suites_shape():
    suites = corpus.build_probe_suites(20, 1234)
    assert [s.name for s in suites] == [
        "cloze", "agreement", "ordering", "copy", "arith", "parity", "retrieval",
    ]
    for s in suites:
        assert len(s.probes) == 20
        for p in s.probes:
            assert 0 <= p.answer < len(p.candidates)
            assert len(set(p.candidates)) == len(p.candidates)
            assert 2 <= len(p.candidates) <= 4


def test_probe_answers_consistent_with_rules():
    suites = {s.name: s for s in corpus.build_probe_suites(30, 5)}
    for p in suites["parity"].probes:
        n = int(p.prompt.split()[0])
        want = " even" if n % 2 == 0 else " odd"
        assert p.candidates[p.answer] == want
    for p in suites["arith"].probes:
        a, _, b, _ = p.prompt.split()
        assert p.candidates[p.answer] == f" {int(a) + int(b)}"
    for p in suites["copy"].probes:
        w = p.prompt.split()[0]
        assert p.candidates[p.answer] == f" {w}"


def test_patterns_present_in_training_corpus():
    """The probe families must be learnable: their supervision patterns must
    actually appear in the training text."""
    text = corpus.build_training_corpus(2000, 1234)
    assert " + " in text and " = " in text       # arith
    assert " is even ." in text and " is odd ." in text  # parity
    assert "recall" in text and "gives" in text  # retrieval
    assert "a b c d e" in text or "b c d e f" in text  # ordering
