# msb_quant — build entry points.
#
# Tier-1 verify: `make build test` (== cargo build --release && cargo test -q)

CARGO ?= cargo

.PHONY: build test test-faults bench-smoke bench-perf bench-pack bench-gemv bench-forward bench-serve bench-spec bench-all lint fmt artifacts clean

## Release build of the library, `msb` CLI, all benches and all examples.
build:
	$(CARGO) build --release --workspace --all-targets

## Full test suite (unit + integration + doctests). Hermetic: tests that
## need artifacts/ skip when it is absent.
test:
	$(CARGO) test -q

## Fault-injection grid: scripted step panics / NaN logits / drafter
## panics / deadline+overload pressure against the serving layer
## (server::faults). Asserts quarantine-only blast radius, survivor
## bit-identity, and zero leaked arena pages.
test-faults:
	$(CARGO) test -q fault

## Fast pass over representative paper-table benches (small instances).
bench-smoke:
	MSB_BENCH_FAST=1 $(CARGO) bench --bench table2_mse_proxy
	MSB_BENCH_FAST=1 $(CARGO) bench --bench table3_quant_time
	MSB_BENCH_FAST=1 $(CARGO) bench --bench fig2_3_loss_vs_size

## Engine/solver hot-path throughput + the scheduler ablation; both
## binaries merge into one BENCH_perf.json (method → blocks/sec,
## merge-kernel arms, sched-* keys) committed at the repo root so the perf
## trajectory accumulates. Set MSB_BENCH_FAST=1 for a smoke-sized run.
bench-perf:
	MSB_BENCH_JSON=$(CURDIR)/BENCH_perf.json $(CARGO) bench --bench perf_hotpath
	MSB_BENCH_JSON=$(CURDIR)/BENCH_perf.json $(CARGO) bench --bench table3_quant_time

## Packed-payload pipeline: pack/decode blocks/sec + packed-bytes ratio,
## self-asserting decode bit-identity; writes BENCH_pack.json (same
## conventions as bench-perf).
bench-pack:
	$(CARGO) bench --bench perf_pack

## Fused packed-weight GEMV vs decode-then-matmul ablation (gemv-* and
## int8-* keys merged into the same BENCH_perf.json as bench-perf).
## Self-asserting: fused must match the reference, beat the decode
## baseline, allocate no f32 weight buffer (peak-allocation gate), and
## the int8 MAC arm must beat the f32 fused path at equal threads.
bench-gemv:
	MSB_BENCH_JSON=$(CURDIR)/BENCH_perf.json $(CARGO) bench --bench perf_gemv

## Fused CPU transformer forward: full-sequence scoring and KV-cached
## incremental decode on a synthetic packed model (forward-* keys merged
## into BENCH_perf.json). Self-asserting: quantized logits must match the
## f32 twin to 1e-4, threads must be bit-identical to serial, and the KV
## cache must beat per-position full recompute.
bench-forward:
	MSB_BENCH_JSON=$(CURDIR)/BENCH_perf.json $(CARGO) bench --bench perf_forward

## Continuous-batching decode over the paged KV arena (serve-* keys
## merged into BENCH_perf.json). Self-asserting: batched logits must be
## bit-identical to solo across MAC/kernel/thread grid, batched decode
## must strictly beat solo sequential at >=2 streams, and the arena's
## peak footprint must stay within the naive per-request caches with
## pages provably recycled across waves.
bench-serve:
	MSB_BENCH_JSON=$(CURDIR)/BENCH_perf.json $(CARGO) bench --bench perf_serve

## Self-speculative greedy decode: draft-verify chunks through step_batch
## with page-level KV rollback (spec-* keys merged into BENCH_perf.json).
## Self-asserting: speculative generation must be bit-identical to plain
## and solo greedy decode across the MAC/kernel/thread grid, take strictly
## fewer step_batch calls on a provably-accepting workload, and keep the
## arena peak within ceil(draft_len/page_tokens) pages of the plain peak.
bench-spec:
	MSB_BENCH_JSON=$(CURDIR)/BENCH_perf.json $(CARGO) bench --bench perf_spec

## Every BENCH_perf.json producer in one pass (plus the pack pipeline's
## BENCH_pack.json). Each binary stamps its keys with a `sources` entry,
## so a full refresh leaves an attributable provenance map behind.
bench-all:
	MSB_BENCH_JSON=$(CURDIR)/BENCH_perf.json $(CARGO) bench --bench perf_hotpath
	MSB_BENCH_JSON=$(CURDIR)/BENCH_perf.json $(CARGO) bench --bench table3_quant_time
	MSB_BENCH_JSON=$(CURDIR)/BENCH_perf.json $(CARGO) bench --bench perf_gemv
	MSB_BENCH_JSON=$(CURDIR)/BENCH_perf.json $(CARGO) bench --bench perf_forward
	MSB_BENCH_JSON=$(CURDIR)/BENCH_perf.json $(CARGO) bench --bench perf_serve
	MSB_BENCH_JSON=$(CURDIR)/BENCH_perf.json $(CARGO) bench --bench perf_spec
	$(CARGO) bench --bench perf_pack

## Style gate: rustfmt + clippy with warnings denied.
lint:
	$(CARGO) fmt --all -- --check
	$(CARGO) clippy --workspace --all-targets -- -D warnings

## Apply formatting in place.
fmt:
	$(CARGO) fmt --all

## Build-time artifacts (trained models, HLO text, token corpora) come from
## the JAX layer. Not buildable in an offline Rust-only environment.
artifacts:
	@echo "make artifacts requires JAX (python/compile/*): it trains the"
	@echo "stand-in transformers, lowers them to HLO text and writes"
	@echo "artifacts/{manifest.json,*.msbt,*.hlo.txt}."
	@echo
	@echo "  pip install jax  # CPU is enough"
	@echo "  cd python && python -m compile.aot --out ../artifacts"
	@echo
	@echo "Everything in rust/ builds, tests and benches without artifacts;"
	@echo "artifact-dependent paths skip or fall back to synthetic data."
	@exit 1

clean:
	$(CARGO) clean
